"""Quickstart: serve a small LM with the Splitwiser engine.

    pip install -e .            # or: export PYTHONPATH=src
    python examples/quickstart.py

Builds the paper's model (opt-125m dims, reduced for CPU) and walks the
vLLM-shaped API surface:

  1. per-request ``SamplingParams`` — a greedy request, a temperature-
     sampled one, and one that stops on a stop token, all in one batch;
  2. streaming ``TokenEvent``s from ``Engine.stream()`` and final
     ``RequestOutput``s from ``Engine.poll()``;
  3. the three execution arms from the paper (sequential, splitwiser,
     splitwiser+MPS) producing identical greedy tokens.
"""
import jax

from repro.configs import ServeConfig, get_config
from repro.core.engine import Engine, Request
from repro.core.sampler import SamplingParams
from repro.data import report_tokens
from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model


def serve_config(mode):
    return ServeConfig(mode=mode, max_batch=4, page_size=16, n_pages=256,
                       max_pages_per_seq=8, prefill_chunk=32, n_streams=2)


def make_requests(prompts, stop_token):
    """One batch, three different per-request sampling policies."""
    greedy = SamplingParams(max_new_tokens=10)
    sampled = SamplingParams(max_new_tokens=10, temperature=0.8, top_k=40,
                             seed=7)
    short = SamplingParams(max_new_tokens=10, stop_token_ids=(stop_token,))
    policies = [greedy, sampled, short]
    return [Request(rid=i, prompt=list(p), sampling=policies[i % 3])
            for i, p in enumerate(prompts)]


def main():
    cfg = get_config("opt-125m").reduced()
    model = Model("opt-125m", cfg, FAMILY_MODULE[cfg.family],
                  CACHE_KIND[cfg.family])
    params = model.init(jax.random.PRNGKey(0))
    prompts = report_tokens(6, 48, cfg.vocab_size)

    # learn a token the model actually emits for prompt 2, so the
    # stop-token policy demonstrably fires (finish_reason="stop")
    probe = Engine(model, params, serve_config("sequential"))
    pr = Request(rid=0, prompt=list(prompts[2]),
                 sampling=SamplingParams(max_new_tokens=2))
    probe.run([pr])
    stop_token = pr.out_tokens[-1]

    # --- streaming: watch tokens arrive (splitwiser_mps arm) -------------
    eng = Engine(model, params, serve_config("splitwiser_mps"))
    n_events = 0
    for ev in eng.stream(make_requests(prompts, stop_token)):
        n_events += 1
        if ev.first or ev.finish_reason:
            tag = "first" if ev.first else f"done({ev.finish_reason})"
            print(f"  [stream] rid={ev.rid} token#{ev.index}={ev.token:4d} {tag}")
    outputs = {o.rid: o for o in eng.poll()}
    print(f"streamed {n_events} TokenEvents; "
          f"finish reasons: { {r: o.finish_reason for r, o in sorted(outputs.items())} }")
    assert outputs[2].finish_reason == "stop", "stop-token demo must fire"
    print(f"rid=0 output: {outputs[0].tokens}  "
          f"TTFT={outputs[0].ttft:.3f}s TBT={(outputs[0].tbt or 0):.4f}s\n")

    # --- the paper's three arms on the same mixed workload ---------------
    per_mode = {}
    for mode in ["sequential", "splitwiser", "splitwiser_mps"]:
        eng = Engine(model, params, serve_config(mode))
        reqs = make_requests(prompts, stop_token)
        s = eng.run(reqs).summary()
        per_mode[mode] = [r.out_tokens for r in reqs]
        print(f"{mode:16s} steps={s['n_steps']:4d} "
              f"wall={s['wall_s']:.2f}s tput={s['throughput_tok_s']:7.1f} tok/s "
              f"TTFT={s['ttft']['mean']:.3f}s KVpeak={s['kv_usage_peak']:.0%}")
    assert per_mode["sequential"] == per_mode["splitwiser"] == \
        per_mode["splitwiser_mps"], "modes must agree token-for-token"
    print("\nall three arms produce identical tokens per request "
          "(seeded sampling is batch- and mode-independent)")


if __name__ == "__main__":
    main()
