"""Quickstart: serve a small LM with the Splitwiser engine.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's model (opt-125m dims, reduced for CPU), submits a batch
of synthetic radiology-report prompts (the paper's MIMIC-III stand-in),
and compares the three execution arms from the paper: sequential,
splitwiser (time-sliced phases), splitwiser+MPS (fused mixed batching).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ServeConfig, get_config
from repro.core.engine import Engine, Request
from repro.data import report_tokens
from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model


def main():
    cfg = get_config("opt-125m").reduced()
    model = Model("opt-125m", cfg, FAMILY_MODULE[cfg.family],
                  CACHE_KIND[cfg.family])
    params = model.init(jax.random.PRNGKey(0))
    prompts = report_tokens(8, 64, cfg.vocab_size)

    for mode in ["sequential", "splitwiser", "splitwiser_mps"]:
        serve = ServeConfig(mode=mode, max_batch=4, page_size=16, n_pages=256,
                            max_pages_per_seq=8, prefill_chunk=32, n_streams=2)
        eng = Engine(model, params, serve)
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=12)
                for i, p in enumerate(prompts)]
        m = eng.run(reqs)
        s = m.summary()
        print(f"{mode:16s} steps={s['n_steps']:4d} "
              f"wall={s['wall_s']:.2f}s tput={s['throughput_tok_s']:7.1f} tok/s "
              f"TTFT={s['ttft']['mean']:.3f}s KVpeak={s['kv_usage_peak']:.0%}")
    print("\nall three arms produce identical greedy tokens "
          "(verified in tests/test_system.py)")


if __name__ == "__main__":
    main()
