"""End-to-end training driver: train a ~100M-param qwen3-family model for a
few hundred steps on the synthetic pipeline, with checkpointing enabled.

    pip install -e .            # or: export PYTHONPATH=src
    python examples/train_100m.py [--steps 300] [--tiny]

--tiny uses the reduced config (CI/CPU-friendly); the default builds a
~100M-parameter variant (scaled-down qwen3: 12L x 512d) that trains on CPU
at a few steps/min. On a TPU mesh the same Trainer runs the full configs
(see src/repro/launch/train.py).
"""
import argparse
import dataclasses
import time


from repro.configs import TrainConfig, get_config
from repro.data import make_train_data_fn
from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    base = get_config("qwen3-0.6b")
    if args.tiny:
        cfg = base.reduced()
    else:  # ~100M params
        cfg = dataclasses.replace(
            base, name="qwen3-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=32_000)
    model = Model(cfg.name, cfg, FAMILY_MODULE[cfg.family],
                  CACHE_KIND[cfg.family])
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=1e-3,
                       warmup_steps=20, total_steps=args.steps,
                       ckpt_dir="/tmp/repro_100m", ckpt_every=100, remat=True)
    trainer = Trainer(model, tcfg, make_train_data_fn(cfg, tcfg),
                      log_every=20)
    from repro.common.tree import tree_count
    print(f"{cfg.name}: {tree_count(trainer.state['params'])/1e6:.1f}M params; "
          f"resuming from step {trainer.start_step}")
    t0 = time.time()
    for step, loss in trainer.run():
        print(f"step {step:5d}  loss {loss:.4f}")
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.0f}s "
          f"({args.steps * args.batch * args.seq / max(dt,1e-9):.0f} tok/s); "
          f"checkpoints in {tcfg.ckpt_dir}")


if __name__ == "__main__":
    main()
