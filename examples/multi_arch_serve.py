"""Serve different architecture families through the same engine/API:
dense (qwen3), MoE (olmoe), sliding-window+softcap (gemma2) — all reduced
configs, all three Splitwiser arms.

    pip install -e .            # or: export PYTHONPATH=src
    python examples/multi_arch_serve.py
"""
import jax
import numpy as np

from repro.configs import ServeConfig, get_config
from repro.core.engine import Engine, Request
from repro.core.sampler import SamplingParams
from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model


def main():
    rng = np.random.RandomState(0)
    for arch in ["qwen3-0.6b", "olmoe-1b-7b", "gemma2-2b"]:
        cfg = get_config(arch).reduced()
        model = Model(arch, cfg, FAMILY_MODULE[cfg.family],
                      CACHE_KIND[cfg.family])
        params = model.init(jax.random.PRNGKey(0))
        serve = ServeConfig(mode="splitwiser_mps", max_batch=4, page_size=8,
                            n_pages=256, max_pages_per_seq=16,
                            prefill_chunk=8, n_streams=2)
        eng = Engine(model, params, serve)
        reqs = [Request(rid=i,
                        prompt=list(rng.randint(2, cfg.vocab_size, 24)),
                        sampling=SamplingParams(max_new_tokens=8))
                for i in range(6)]
        s = eng.run(reqs).summary()
        print(f"{arch:14s} [{cfg.family:5s}] done={s['n_done']} "
              f"steps={s['n_steps']} tput={s['throughput_tok_s']:7.1f} tok/s "
              f"KVpeak={s['kv_usage_peak']:.0%} "
              f"sample={reqs[0].out_tokens[:4]}")


if __name__ == "__main__":
    main()
