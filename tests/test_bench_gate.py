"""Benchmark-regression gate logic (benchmarks/regression_gate.py).

The CI acceptance bar: the gate must pass on an identical re-run and
fail on an injected hit-rate (or token-count / completion) regression,
while ignoring timing-dependent fields entirely.
"""
import copy
import json
import subprocess
import sys
from pathlib import Path

from benchmarks.regression_gate import compare

BASELINE = {
    "rows": [
        {"bench": "shared_prefix", "x": "mps/K=1/cache", "n_done": 8,
         "all_complete": True, "prefill_tokens": 128, "cached_tokens": 96,
         "hit_rate": 0.42, "throughput_tok_s": 1234.5},
        {"bench": "midpage_delta", "x": "mps", "prefill_tokens_page": 144,
         "prefill_tokens_token": 84, "hit_rate_page": 0.0,
         "hit_rate_token": 0.41, "n_partial_hits": 4, "tokens_match": True},
    ],
    "checks": [{"msg": "token beats page", "passed": True}],
    "ok": True,
}


def test_identical_run_passes():
    assert compare(BASELINE, copy.deepcopy(BASELINE)) == []


def test_injected_hit_rate_regression_fails():
    fresh = copy.deepcopy(BASELINE)
    fresh["rows"][0]["hit_rate"] = 0.30
    failures = compare(BASELINE, fresh)
    assert len(failures) == 1 and "hit_rate" in failures[0]
    # within tolerance: noise-level wiggle passes
    fresh["rows"][0]["hit_rate"] = 0.41
    assert compare(BASELINE, fresh) == []


def test_count_and_completion_regressions_fail():
    fresh = copy.deepcopy(BASELINE)
    fresh["rows"][0]["n_done"] = 7
    fresh["rows"][0]["prefill_tokens"] = 200
    fresh["rows"][1]["tokens_match"] = False
    fresh["rows"][1]["n_partial_hits"] = 0
    msgs = "\n".join(compare(BASELINE, fresh))
    assert "n_done" in msgs and "prefill_tokens" in msgs
    assert "tokens_match" in msgs and "n_partial_hits" in msgs


def test_scheduler_health_counters_gated():
    base = {
        "rows": [{"bench": "pressure_oversubscribed", "x": "mps",
                  "n_preemptions": 3, "n_preempted_requests": 2,
                  "n_reclaims": 5, "seed_crash": True}],
        "checks": [],
    }
    fresh = copy.deepcopy(base)
    fresh["rows"][0]["n_preemptions"] = 9      # thrash: max-gated
    fresh["rows"][0]["seed_crash"] = False     # pool no longer oversubscribed
    msgs = "\n".join(compare(base, fresh))
    assert "n_preemptions" in msgs and "seed_crash" in msgs
    # fewer preemptions/reclaims is an improvement, not a regression
    fresh = copy.deepcopy(base)
    fresh["rows"][0]["n_preemptions"] = 0
    fresh["rows"][0]["n_reclaims"] = 0
    assert compare(base, fresh) == []


def test_timing_fields_ignored():
    fresh = copy.deepcopy(BASELINE)
    fresh["rows"][0]["throughput_tok_s"] = 1.0     # 1000x slower: not gated
    assert compare(BASELINE, fresh) == []


def test_missing_scenario_and_flipped_check_fail():
    fresh = copy.deepcopy(BASELINE)
    del fresh["rows"][1]
    fresh["checks"][0]["passed"] = False
    msgs = compare(BASELINE, fresh)
    assert any("missing" in m for m in msgs)
    assert any("validation check now failing" in m for m in msgs)
    # a check that vanishes (reworded/removed without a baseline refresh)
    # fails just as loudly as a flipped one
    fresh = copy.deepcopy(BASELINE)
    fresh["checks"] = []
    assert any("validation check missing" in m
               for m in compare(BASELINE, fresh))
    # new rows in fresh (no baseline yet) never fail
    fresh = copy.deepcopy(BASELINE)
    fresh["rows"].append({"bench": "new_scenario", "x": "y", "hit_rate": 0.0})
    assert compare(BASELINE, fresh) == []


def test_cli_exit_codes(tmp_path: Path):
    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps(BASELINE))
    fresh = copy.deepcopy(BASELINE)
    fresh["rows"][1]["hit_rate_token"] = 0.1
    fresh_p = tmp_path / "fresh.json"
    fresh_p.write_text(json.dumps(fresh))
    repo = Path(__file__).resolve().parent.parent
    ok = subprocess.run(
        [sys.executable, "-m", "benchmarks.regression_gate",
         str(base_p), str(base_p)], cwd=repo, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(
        [sys.executable, "-m", "benchmarks.regression_gate",
         str(base_p), str(fresh_p)], cwd=repo, capture_output=True, text=True)
    assert bad.returncode == 1 and "hit_rate_token" in bad.stdout


def test_committed_baseline_is_self_consistent():
    """The committed BENCH_baseline.json must parse and pass against
    itself — catches hand-edits that would make every CI run red."""
    repo = Path(__file__).resolve().parent.parent
    with open(repo / "BENCH_baseline.json") as fp:
        baseline = json.load(fp)
    assert baseline["rows"], "baseline has no rows"
    benches = {r["bench"] for r in baseline["rows"]}
    assert {"shared_prefix", "midpage_divergence", "midpage_delta",
            "pressure_oversubscribed", "policy_sweep",
            "policy_sweep_delta"} <= benches
    assert compare(baseline, copy.deepcopy(baseline)) == []
