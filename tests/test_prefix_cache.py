"""Shared-prefix KV cache: refcounted copy-on-write page sharing.

The contract under test: with ``enable_prefix_cache=True`` the engine
produces *bit-identical* greedy token streams in every mode while doing
strictly less prefill work on shared prompts, preempted requests resume
by remapping their own just-freed pages, and pressure strips reclaimable
cached pages before anyone is preempted.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.kv_cache import PageAllocator
from repro.core.prefix_cache import PrefixCache

ARCH = "qwen3-0.6b"
MODES = ["sequential", "splitwiser", "splitwiser_mps"]
PS = 4
BASE = ServeConfig(max_batch=4, page_size=PS, n_pages=128,
                   max_pages_per_seq=16, prefill_chunk=PS, n_streams=2,
                   enable_prefix_cache=True)


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _shared_prefix_requests(vocab, n=6, sys_tokens=24, tail=4, out=8, seed=0):
    rng = np.random.RandomState(seed)
    system = list(rng.randint(2, vocab, size=sys_tokens))
    return [Request(rid=i,
                    prompt=system + list(rng.randint(2, vocab, size=tail)),
                    sampling=SamplingParams(max_new_tokens=out))
            for i in range(n)]


def _run(model, params, serve, reqs):
    eng = Engine(model, params, serve)
    m = eng.run(reqs, max_steps=8000)
    return eng, m.summary()


# ------------------------------------------------- engine-level behavior ---
@pytest.mark.parametrize("mode", MODES)
def test_greedy_bit_identical_cache_on_off(setup, mode):
    """The cache must be a pure optimization: same tokens, less work."""
    model, params = setup
    outs, summaries = {}, {}
    for cache in (False, True):
        serve = dataclasses.replace(BASE, mode=mode,
                                    enable_prefix_cache=cache)
        reqs = _shared_prefix_requests(model.cfg.vocab_size)
        _, s = _run(model, params, serve, reqs)
        assert s["n_done"] == len(reqs)
        outs[cache] = [r.out_tokens for r in reqs]
        summaries[cache] = s
    assert outs[True] == outs[False]
    assert summaries[True]["cache_hit_rate"] > 0
    assert summaries[False]["cache_hit_rate"] == 0
    assert (summaries[True]["prefill_tokens_computed"]
            < summaries[False]["prefill_tokens_computed"])
    assert summaries[True]["pages_shared_peak"] > 0


def test_disjoint_prompts_never_hit(setup):
    """Unrelated prompts must not alias: zero hits, zero shared pages."""
    model, params = setup
    rng = np.random.RandomState(7)
    reqs = [Request(rid=i,
                    prompt=list(rng.randint(2, model.cfg.vocab_size, size=20)),
                    sampling=SamplingParams(max_new_tokens=4))
            for i in range(4)]
    eng, s = _run(model, params,
                  dataclasses.replace(BASE, mode="splitwiser_mps"), reqs)
    assert s["n_done"] == 4
    assert s["cache_hit_rate"] == 0
    assert s["cached_tokens"] == 0
    assert s["pages_shared_peak"] == 0


@pytest.mark.parametrize("mode", MODES)
def test_cow_divergence_after_shared_prefix(setup, mode):
    """Requests sharing a prefix but with different tails must write their
    divergent KV into private pages — outputs match the independent
    (cache-off, generous-pool) runs exactly while prefix pages are shared."""
    model, params = setup
    reqs = _shared_prefix_requests(model.cfg.vocab_size, n=6, tail=6)
    serve = dataclasses.replace(BASE, mode=mode)
    eng, s = _run(model, params, serve, reqs)
    ref = _shared_prefix_requests(model.cfg.vocab_size, n=6, tail=6)
    _, _ = _run(model, params,
                dataclasses.replace(BASE, mode=mode,
                                    enable_prefix_cache=False), ref)
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in ref]
    assert s["pages_shared_peak"] > 0        # prefix pages really were shared
    # every request generated distinct continuations from the shared prefix
    assert len({tuple(r.prompt + r.out_tokens) for r in reqs}) == len(reqs)


@pytest.mark.parametrize("mode", MODES)
def test_preempted_resume_remaps_own_pages(setup, mode):
    """A preempted victim's pages park in the cache; its resume must re-hit
    them (remap, not recompute) and still produce oracle-exact greedy."""
    model, params = setup
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(2, model.cfg.vocab_size, size=n))
               for n in (12, 11, 12, 10)]

    def reqs():
        return [Request(rid=i, prompt=list(p),
                        sampling=SamplingParams(max_new_tokens=16))
                for i, p in enumerate(prompts)]

    oracle = reqs()
    _run(model, params, dataclasses.replace(
        BASE, mode="sequential", enable_prefix_cache=False), oracle)

    small = dataclasses.replace(BASE, mode=mode, n_pages=20,
                                max_pages_per_seq=12)
    eng = Engine(model, params, small)
    rs = reqs()
    m = eng.run(rs, max_steps=8000)
    s = m.summary()
    assert s["n_done"] == 4
    assert s["n_preemptions"] > 0
    assert [r.out_tokens for r in rs] == [r.out_tokens for r in oracle]
    # at least one resumed request re-hit its own just-freed pages
    resumed = [m.requests[r.rid] for r in rs if m.requests[r.rid].n_preempted]
    assert any(r.n_cached_tokens > 0 for r in resumed)
    resumed_admits = [e for e in m.sched_events
                     if e["event"] == "admit" and e.get("resumed")]
    assert any(e.get("cached_pages", 0) > 0 for e in resumed_admits)
    assert eng.alloc.n_allocated == 0 and eng.idle()


def test_reclaim_strips_cache_before_preemption(setup):
    """Zero-ref cached pages are the first pressure valve: a workload that
    fits only because finished requests' pages are reclaimed must complete
    with reclaim events and WITHOUT preempting anyone."""
    model, params = setup
    rng = np.random.RandomState(3)
    vocab = model.cfg.vocab_size
    # pool of 15 usable pages; each request needs ceil((16+1+2)/4) = 5
    serve = dataclasses.replace(BASE, mode="sequential", n_pages=16,
                                max_batch=1, decode_reserve=0.5)
    eng = Engine(model, params, serve)
    # sequential single-slot: requests run one after another; each leaves
    # its pages parked reclaimable, which later disjoint requests strip
    reqs = [Request(rid=i, prompt=list(rng.randint(2, vocab, size=16)),
                    sampling=SamplingParams(max_new_tokens=4))
            for i in range(6)]
    m = eng.run(reqs, max_steps=8000)
    s = m.summary()
    assert s["n_done"] == 6
    assert s["n_preemptions"] == 0
    assert s["n_reclaims"] > 0
    assert any(e["event"] == "reclaim" for e in m.sched_events)


def test_request_output_reports_cached_tokens(setup):
    model, params = setup
    serve = dataclasses.replace(BASE, mode="splitwiser_mps")
    eng = Engine(model, params, serve)
    reqs = _shared_prefix_requests(model.cfg.vocab_size, n=4)
    eng.run(reqs, max_steps=8000)
    outs = {o.rid: o for o in eng.poll()}
    assert len(outs) == 4
    assert any(o.n_cached_tokens > 0 for o in outs.values())


# -------------------------------------------------------- allocator units --
def _alloc(n_pages=16, ps=4, policy="lru"):
    cache = PrefixCache(ps, policy=policy)
    return PageAllocator(n_pages, ps, cache=cache), cache


def test_refcounted_share_and_release():
    alloc, cache = _alloc()
    pages = alloc.alloc(1, 3)
    cache.insert(list(range(12)), pages)
    alloc.share(2, pages)
    assert alloc.n_pages_shared == 3
    assert alloc.n_exclusive(1) == 0      # every page shared with rid 2
    # still referenced by rid 2: nothing actually freed, nothing reclaimable
    assert alloc.free(1) == 0
    assert cache.n_reclaimable == 0 and alloc.n_pages_shared == 0
    assert alloc.n_exclusive(2) == 3
    alloc.free(2)
    # now zero-ref but cached: parked reclaimable, still counted free
    assert cache.n_reclaimable == 3
    assert alloc.n_free == 15 and alloc.n_allocated == 0


def test_match_revives_reclaimable_and_reclaim_evicts_lru_leaf():
    alloc, cache = _alloc(n_pages=8, ps=4)
    a = alloc.alloc(1, 2)
    cache.insert(list(range(8)), a)
    alloc.free(1)
    assert cache.n_reclaimable == 2
    # match + share revives the chain (ref 0 -> 1)
    hit = cache.match(list(range(8)) + [99])
    assert hit == a
    alloc.share(2, hit)
    assert cache.n_reclaimable == 0
    alloc.free(2)
    # exhaust the free list; next alloc must strip reclaimable pages
    free_left = len(alloc._free)
    alloc.alloc(3, free_left)
    assert alloc.n_reclaims == 0
    alloc.alloc(3, 1)
    assert alloc.n_reclaims == 1
    # the LRU *leaf* (deepest chain node) went first: the surviving node
    # still matches the first page of the prefix
    assert cache.match(list(range(8))) == a[:1]


def test_fifo_policy_and_validation():
    with pytest.raises(ValueError, match="prefix_cache_policy"):
        PrefixCache(4, policy="mru")
    with pytest.raises(ValueError, match="prefix_cache_policy"):
        ServeConfig(enable_prefix_cache=True, prefix_cache_policy="bad")
    alloc, cache = _alloc(n_pages=12, ps=4, policy="fifo")
    a = alloc.alloc(1, 1)
    cache.insert(list(range(4)), a)
    b = alloc.alloc(2, 1)
    cache.insert(list(range(100, 104)), b)
    alloc.free(1)
    alloc.free(2)
    cache.touch(a)     # LRU would now evict b first; FIFO still evicts a
    assert cache.pop_reclaimable() == a[0]


def test_cow_splits_shared_tail_page():
    """prepare_write on a shared page gives the writer a private copy and
    leaves the original with the other reader (and the cache)."""
    alloc, cache = _alloc()
    pages = alloc.alloc(1, 2)
    cache.insert(list(range(8)), pages)
    alloc.share(2, pages)
    # rid 2 is about to write into its tail page (position 5 -> page 1)
    pairs = alloc.prepare_write(2, 5)
    assert len(pairs) == 1
    src, dst = pairs[0]
    assert src == pages[1] and dst not in pages
    assert alloc.owned(2) == [pages[0], dst]
    assert alloc.owned(1) == pages            # reader untouched
    assert cache.is_cached(src) and not cache.is_cached(dst)
    # a second write to the now-private page is a no-op... rid 1 still
    # shares page 0 with rid 2, so writing THERE would split again
    assert alloc.prepare_write(2, 6) == []
    assert len(alloc.prepare_write(2, 1)) == 1


def test_cow_on_cached_exclusive_page_preserves_cache_content():
    """Even with a single reference, a *cached* page must not be written
    in place — the cache's copy would silently diverge from its key."""
    alloc, cache = _alloc()
    pages = alloc.alloc(1, 1)
    cache.insert(list(range(4)), pages)
    pairs = alloc.prepare_write(1, 2)
    assert len(pairs) == 1
    src, dst = pairs[0]
    assert src == pages[0]
    assert cache.is_cached(src)
    # the original parked reclaimable (zero-ref, cached)
    assert cache.n_reclaimable == 1
    assert alloc.owned(1) == [dst]


# ------------------------------------------------------------ trie units ---
def test_trie_partial_tail_needs_explicit_opt_in():
    cache = PrefixCache(4)
    alloc = PageAllocator(16, 4, cache=cache)
    pages = alloc.alloc(1, 2)
    # 6 tokens = 1 full page + a partial tail: mid-flight inserts must
    # trim to full pages (the tail is still being written); only
    # terminal inserts may register it (token-level reuse opt-in)
    with pytest.raises(ValueError):
        cache.insert(list(range(6)), pages)
    cache.insert(list(range(4)), pages[:1])
    assert cache.match(list(range(6))) == pages[:1]
    assert cache.match([9, 9, 9, 9]) == []


def test_trie_duplicate_insert_keeps_first_pages():
    cache = PrefixCache(2)
    assert cache.insert([1, 2, 3, 4], [10, 11]) == 2
    # a concurrent private recompute of the same prefix: not re-registered
    assert cache.insert([1, 2, 3, 4], [12, 13]) == 0
    assert cache.match([1, 2, 3, 4]) == [10, 11]
    # diverging second page chains a sibling under the shared first node
    assert cache.insert([1, 2, 7, 8], [14, 15]) == 1
    assert cache.match([1, 2, 7, 8]) == [10, 15]
