"""Sharding-spec validity for every arch at the production TP degree, the
loop-aware cost model units, and a subprocess multi-device dry-run smoke
(keeps XLA_FLAGS out of this process per the assignment)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_divisible_at_tp16(arch):
    """Every sharded dim must divide by its mesh-axis size (16/16)."""
    from repro.launch.shardings import param_pspecs
    from repro.launch.steps import get_model
    model = get_model(arch)
    cfg = model.cfg
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                               jnp.bfloat16, tp=16))
    specs = param_pspecs(shapes, cfg, tp=16, fsdp_size=16, fsdp="data")
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(flat_shapes) == len(flat_specs)
    n_sharded = 0
    for leaf, spec in zip(flat_shapes, flat_specs, strict=True):
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            n_sharded += 1
            size = 16
            assert leaf.shape[i] % size == 0, (arch, leaf.shape, spec)
    assert n_sharded > 0, arch          # something actually shards


def test_jaxpr_costs_exact_on_matmul_and_scan():
    from repro.launch.costs import traced_costs

    def f(a, b):
        return a @ b

    a = jnp.zeros((8, 32), jnp.float32)
    b = jnp.zeros((32, 16), jnp.float32)
    c = traced_costs(f, a, b)
    assert c["flops"] == 2 * 8 * 32 * 16
    # scan multiplies by trip count (XLA cost_analysis famously does not)
    def g(a, b):
        def body(x, _):
            return x @ b, ()
        out, _ = jax.lax.scan(body, a, None, length=10)
        return out
    sq = jnp.zeros((32, 32), jnp.float32)
    c2 = traced_costs(g, jnp.zeros((8, 32)), sq)
    assert c2["flops"] == 10 * 2 * 8 * 32 * 32


def test_collective_parser_trip_multiplier():
    from repro.launch.costs import collective_bytes_loop_aware
    hlo = """
body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(f32[64]{0} %x), replica_groups={}
}
cond.1 (p: (s32[], f32[64])) -> pred[] {
  %c = s32[] constant(28)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}
ENTRY main (a: f32[64]) -> f32[64] {
  %ag = f32[128]{0} all-gather(f32[64]{0} %a), dimensions={0}
  %w = (s32[], f32[64]) while((s32[], f32[64]) %t), condition=%cond.1, body=%body.1
}
"""
    out = collective_bytes_loop_aware(hlo)
    assert out["all-reduce"] == 28 * 64 * 4        # trip-multiplied
    assert out["all-gather"] == 64 * 4             # operand bytes, once


@pytest.mark.parametrize("case", [
    ("qwen3-0.6b", "decode_32k"),
    ("olmoe-1b-7b", "mixed_32k"),
    ("rwkv6-7b", "train_4k"),
])
def test_multi_device_cell_compiles_subprocess(case):
    """Real 8-device sharded lower+compile in a subprocess (XLA_FLAGS set
    only there). Shapes shrunk; mesh (2 data x 4 model)."""
    arch, shape = case
    code = f"""
import jax
import repro.launch.steps as steps
from repro.launch.shardings import named
steps.SHAPES['train_4k'] = dict(kind='train', seq=512, batch=8)
steps.SHAPES['decode_32k'] = dict(kind='decode', seq=1024, batch=8)
steps.SHAPES['mixed_32k'] = dict(kind='mixed', seq=1024, batch=8, chunk=64, streams=2)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
cell, why = steps.build_cell({arch!r}, {shape!r}, mesh)
assert cell is not None, why
jitted = jax.jit(cell['fn'], in_shardings=named(mesh, cell['in_shardings']),
                 donate_argnums=cell['donate'])
compiled = jitted.lower(*cell['args']).compile()
print('COMPILED_OK', compiled.memory_analysis().temp_size_in_bytes >= 0)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    assert "COMPILED_OK" in r.stdout, r.stderr[-2000:]


def test_mesh_factory():
    """make_production_mesh shapes/axes (single pod only on 1 device it
    cannot build — validated in the dry-run; here check the multi-pod
    factory arithmetic via a subprocess)."""
    code = """
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.shape == {'data': 16, 'model': 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert m2.shape == {'pod': 2, 'data': 16, 'model': 16}, m2.shape
assert m2.size == 512
print('MESH_OK')
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "MESH_OK" in r.stdout, r.stderr[-2000:]
