"""Hypothesis properties for the chunked-prefill subsystem.

Two layers (the deterministic chunked suite lives in
``test_chunked.py``; this file needs hypothesis and skips without it):

* **Planner drain**: any (remaining, decodes) round yields a plan that
  passes its own :func:`validate_plan`, never over-packs the budget the
  decodes left over, and — driven round by round — drains a workload
  exactly when the budget allows prefill progress at all.
* **Engine interleavings**: random chunk boundary (aligned and mid-page
  budgets) x partial-prefix hit (shared prefixes ending mid-page) x
  preemption/resume (a 16-page pool oversubscribes) keep greedy streams
  oracle-exact with the step-level sanitizer on, so every eviction runs
  through the differential preempt/resume checker and every round's
  plan through the ``chunk_plan`` packing invariant.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import reduced_model
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.planner import ChunkPlanner, validate_plan

# "ci" profile (HYPOTHESIS_PROFILE=ci): fixed seed, no deadline — property
# tests cannot time out or flake on slow shared runners.
settings.register_profile(
    "ci", max_examples=40, deadline=None, derandomize=True,
    database=None, print_blob=False)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))

ARCH = "qwen3-0.6b"
PS = 4
BASE = ServeConfig(mode="chunked", max_batch=3, page_size=PS, n_pages=26,
                   max_pages_per_seq=12, prefill_chunk=PS, n_streams=2,
                   chunk_tokens=8, enable_prefix_cache=True)


# ------------------------------------------------------- planner drain ----
@given(budget=st.integers(1, 64), n_streams=st.integers(1, 4),
       n_decode=st.integers(0, 12), data=st.data())
@settings(max_examples=60, deadline=None)
def test_planner_always_emits_valid_plans(budget, n_streams, n_decode, data):
    p = ChunkPlanner(budget, n_streams)
    remaining = data.draw(st.lists(st.integers(0, 100), min_size=n_streams,
                                   max_size=n_streams))
    total = sum(remaining)
    for _ in range(sum(remaining) + 1):
        plan = p.plan(remaining, n_decode)
        validate_plan(plan, remaining, n_decode)
        assert plan.n_prefill_tokens <= max(budget - n_decode, 0)
        remaining = [r - c for r, c in zip(remaining, plan.chunk_lens)]
        total -= plan.n_prefill_tokens
        if plan.n_prefill_tokens == 0:
            break
    # either the workload drained, or decodes saturate the budget and no
    # prefill progress is possible by contract
    assert (total == 0) or (budget <= n_decode)


# ------------------------------------------------ engine interleavings ----
@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


_ORACLES = {}   # workload signature -> greedy streams (sequential ref)


def _oracle_streams(model, params, prompts, n_new):
    key = (tuple(tuple(p) for p in prompts), n_new)
    if key not in _ORACLES:
        serve = dataclasses.replace(BASE, mode="sequential", n_pages=128,
                                    enable_prefix_cache=False)
        reqs = [Request(rid=i, prompt=list(p),
                        sampling=SamplingParams(max_new_tokens=n_new))
                for i, p in enumerate(prompts)]
        Engine(model, params, serve).run(reqs, max_steps=4000)
        _ORACLES[key] = [r.out_tokens for r in reqs]
    return _ORACLES[key]


@given(chunk_tokens=st.integers(PS, 14),
       shared_len=st.integers(4, 13),
       tails=st.lists(st.integers(1, 6), min_size=2, max_size=3),
       n_pages=st.sampled_from([16, 20, 40]),
       n_new=st.integers(3, 6))
@settings(max_examples=8, deadline=None, database=None, derandomize=True)
def test_interleaving_properties(setup, chunk_tokens, shared_len, tails,
                                 n_pages, n_new):
    model, params = setup
    rng = np.random.RandomState(chunk_tokens * 131 + shared_len)
    vocab = model.cfg.vocab_size
    shared = list(rng.randint(2, vocab, size=shared_len))
    prompts = [shared + list(rng.randint(2, vocab, size=t)) for t in tails]
    serve = dataclasses.replace(BASE, chunk_tokens=chunk_tokens,
                                n_pages=n_pages, sanitize_level="step")
    eng = Engine(model, params, serve)
    reqs = [Request(rid=i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=n_new))
            for i, p in enumerate(prompts)]
    s = eng.run(reqs, max_steps=6000).summary()
    assert s["n_done"] == len(reqs)
    assert ([r.out_tokens for r in reqs]
            == _oracle_streams(model, params, prompts, n_new))
    assert eng.alloc.n_allocated == 0 and eng.idle()
    assert not eng.sanitizer._preempt_snaps
