# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real (single-CPU) device. Multi-device sharding is validated either on a
# (1,1) mesh in-process or in subprocesses that set
# --xla_force_host_platform_device_count themselves (test_dryrun_small.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def reduced_model(arch):
    from repro.configs import get_config
    from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model
    cfg = get_config(arch).reduced()
    return Model(arch, cfg, FAMILY_MODULE[cfg.family], CACHE_KIND[cfg.family])


def family_batch(cfg, B, T, key=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key), (B, T), 0,
                                          cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, 16, cfg.d_model)) * 0.3
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(key + 2),
            (B, cfg.n_vision_patches, cfg.d_vision)) * 0.3
    return batch
