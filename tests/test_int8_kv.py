"""int8 KV cache (§Perf beyond-paper optimization): quantized paged
attention must match the bf16 path within quantization tolerance."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.spmd import paged_attention_int8, q8_kv
from repro.models.layers import paged_attention_ref


def test_int8_paged_attention_close_to_fp():
    B, Tq, H, KV, d, ps, N, Pmax = 2, 4, 4, 2, 32, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Tq, H, d)) * 0.5
    k = jax.random.normal(ks[1], (N, ps, KV, d)) * 0.5
    v = jax.random.normal(ks[2], (N, ps, KV, d)) * 0.5
    bt = jnp.asarray(np.random.RandomState(0).permutation(N - 1)
                     [: B * Pmax].reshape(B, Pmax), jnp.int32)
    q_pos = jnp.asarray([10, 3], jnp.int32)
    lens = q_pos + Tq
    want = paged_attention_ref(q, k, v, bt, lens,
                               q_pos[:, None] + jnp.arange(Tq)[None],
                               scale=0.3)
    kq, kscale = q8_kv(k)
    vq, vscale = q8_kv(v)
    got = paged_attention_int8(q, kq, kscale, vq, vscale, bt, lens,
                               q_pos[:, None] + jnp.arange(Tq)[None],
                               scale=0.3, window=None, attn_softcap=None)
    err = float(jnp.abs(got - want).max())
    rel = err / float(jnp.abs(want).max())
    assert rel < 2e-2, (err, rel)           # ~1e-3 typical, 2e-2 bound


def test_q8_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 2, 64))
    q, s = q8_kv(x)
    back = q.astype(jnp.float32) * s
    assert float(jnp.abs(back - x).max()) <= float(s.max()) * 0.5 + 1e-6
