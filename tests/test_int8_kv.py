"""int8 KV cache (§Perf beyond-paper optimization): quantized paged
attention must match the bf16 path within quantization tolerance.

Covers the kernel stack bottom-up: q8_kv edge cases (all-zero planes,
partial tail pages, COW copies), the jnp reference, the Pallas
dequant-in-kernel launcher against that reference, and the engine
end-to-end (greedy int8 streams vs the fp oracle, bit-identical across
all four serve modes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.kernels import ops
from repro.kernels.kv_int8 import SCALE_FLOOR, quant_kv
from repro.launch.spmd import paged_attention_int8, q8_kv
from repro.models.layers import paged_attention_ref


def test_int8_paged_attention_close_to_fp():
    B, Tq, H, KV, d, ps, N, Pmax = 2, 4, 4, 2, 32, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Tq, H, d)) * 0.5
    k = jax.random.normal(ks[1], (N, ps, KV, d)) * 0.5
    v = jax.random.normal(ks[2], (N, ps, KV, d)) * 0.5
    bt = jnp.asarray(np.random.RandomState(0).permutation(N - 1)
                     [: B * Pmax].reshape(B, Pmax), jnp.int32)
    q_pos = jnp.asarray([10, 3], jnp.int32)
    lens = q_pos + Tq
    want = paged_attention_ref(q, k, v, bt, lens,
                               q_pos[:, None] + jnp.arange(Tq)[None],
                               scale=0.3)
    kq, kscale = q8_kv(k)
    vq, vscale = q8_kv(v)
    got = paged_attention_int8(q, kq, kscale, vq, vscale, bt, lens,
                               q_pos[:, None] + jnp.arange(Tq)[None],
                               scale=0.3, window=None, attn_softcap=None)
    err = float(jnp.abs(got - want).max())
    rel = err / float(jnp.abs(want).max())
    assert rel < 2e-2, (err, rel)           # ~1e-3 typical, 2e-2 bound


def test_q8_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 2, 64))
    q, s = q8_kv(x)
    back = q.astype(jnp.float32) * s
    assert float(jnp.abs(back - x).max()) <= float(s.max()) * 0.5 + 1e-6


def test_q8_all_zero_page_has_floored_scale():
    """An all-zero (token, head) plane — pool init, or a genuinely zero
    KV row — must quantize to a positive finite scale, never 0 or NaN:
    downstream dequant multiplies by it inside the attention kernel."""
    q, s = q8_kv(jnp.zeros((2, 8, 2, 32)))
    assert bool(jnp.all(s == SCALE_FLOOR))
    assert bool(jnp.all(jnp.isfinite(s))) and float(s.min()) > 0
    assert bool(jnp.all(q == 0))
    back = q.astype(jnp.float32) * s
    assert bool(jnp.all(back == 0)) and bool(jnp.all(jnp.isfinite(back)))


def test_q8_single_token_tail_page():
    """A tail page holding ONE real token (the rest pool-init zeros)
    quantizes per-token: the real token keeps its own scale and
    roundtrips, the padding rows stay exactly zero."""
    page = jnp.zeros((1, 8, 2, 32))
    tok = jax.random.normal(jax.random.PRNGKey(2), (2, 32)) * 3.0
    page = page.at[0, 0].set(tok)
    q, s = q8_kv(page)
    back = q.astype(jnp.float32) * s
    err = float(jnp.abs(back[0, 0] - tok).max())
    assert err <= float(s[0, 0].max()) * 0.5 + 1e-6
    assert bool(jnp.all(back[0, 1:] == 0))
    assert bool(jnp.all(s[0, 1:] == SCALE_FLOOR))


def test_q8_roundtrip_survives_cow_copy():
    """A COW page copy moves codes AND scales together (the engine
    tree-maps the copy over the {"q","s"} pool dict): the copy must
    dequantize bit-identically to its source."""
    k = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 8, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 8, 2, 16))
    kpg, vpg = quant_kv(k, v)
    src, dst = 1, 3
    kpg = jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), kpg)
    def deq(pg, p):
        return pg["q"][:, p].astype(jnp.float32) * pg["s"][:, p]
    np.testing.assert_array_equal(np.asarray(deq(kpg, dst)),
                                  np.asarray(deq(kpg, src)))


def test_pallas_int8_kernel_matches_jnp_ref():
    """The promoted Pallas dequant-in-kernel launcher against the jnp
    reference (interpret mode on CPU)."""
    B, Tq, H, KV, d, ps, N, Pmax = 2, 4, 4, 2, 32, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Tq, H, d)) * 0.5
    kq, kscale = q8_kv(jax.random.normal(ks[1], (N, ps, KV, d)) * 0.5)
    vq, vscale = q8_kv(jax.random.normal(ks[2], (N, ps, KV, d)) * 0.5)
    bt = jnp.asarray(np.random.RandomState(1).permutation(N - 1)
                     [: B * Pmax].reshape(B, Pmax), jnp.int32)
    q_pos = jnp.asarray([9, 2], jnp.int32)
    lens = q_pos + Tq
    want = paged_attention_int8(q, kq, kscale, vq, vscale, bt, lens,
                                q_pos[:, None] + jnp.arange(Tq)[None],
                                scale=0.3, window=None, attn_softcap=None)
    got = ops.paged_attention_int8(q, kq, kscale, vq, vscale, bt, lens,
                                   q_pos, scale=0.3)
    rel = float(jnp.abs(got - want).max()) / float(jnp.abs(want).max())
    assert rel < 1e-5, rel


# ---------------------------------------------------- engine end-to-end ----
@pytest.fixture(scope="module")
def engine_setup():
    model = reduced_model("qwen3-0.6b")
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.RandomState(7).randint(
        0, model.cfg.vocab_size, (4, 11)).tolist()
    def reqs():
        return [Request(rid=i, prompt=list(p),
                        sampling=SamplingParams(max_new_tokens=6))
                for i, p in enumerate(prompts)]
    return model, params, reqs


BASE = ServeConfig(max_batch=3, page_size=4, n_pages=64, max_pages_per_seq=12,
                   prefill_chunk=4, n_streams=2, enable_prefix_cache=True,
                   sanitize_level="step")


def _streams(model, params, reqs, **over):
    eng = Engine(model, params, dataclasses.replace(BASE, **over))
    m = eng.run(reqs())
    assert m.summary()["n_done"] == 4
    return {o.rid: tuple(o.tokens) for o in eng.poll()}, eng


def test_int8_greedy_matches_fp_oracle_all_modes(engine_setup):
    """Greedy int8 token streams vs the fp oracle, and bit-identical
    across all four serve modes (the tolerance story: on the reduced
    models the argmax never flips; EXPERIMENTS.md documents the logit
    closeness behind it)."""
    model, params, reqs = engine_setup
    oracle, _ = _streams(model, params, reqs, mode="sequential")
    for mode in ("sequential", "splitwiser", "splitwiser_mps", "chunked"):
        got, eng = _streams(model, params, reqs, mode=mode, kv_dtype="int8",
                            chunk_tokens=8 if mode == "chunked" else 16)
        assert got == oracle, mode
        assert eng.metrics.n_quant_pages > 0


def test_int8_pool_grows_at_equal_bytes(engine_setup):
    """The byte-denominated pool: flipping kv_dtype alone must buy
    >= 1.8x the usable pages at (at most) the same device bytes."""
    model, params, reqs = engine_setup
    _, fp = _streams(model, params, reqs)
    _, i8 = _streams(model, params, reqs, kv_dtype="int8")
    assert i8.metrics.kv_pool_bytes <= fp.metrics.kv_pool_bytes
    assert i8.alloc.n_pages >= 1.8 * fp.alloc.n_pages
    assert i8.metrics.kv_bytes_per_token < fp.metrics.kv_bytes_per_token
