"""Call-tier sanitizer (analysis/hooks.py): every hooked mutator proven
live by an injected corruption that its own invariant subset catches at
the mutator's exit, attributed to that exact call site.

Mirrors the mutation-proof discipline of tests/test_sanitizer.py one
level deeper: the step-boundary tests prove the *checks* are live; these
prove the *attribution* is right — the violation names the mutating
method, carries an args digest and the request id, and nested compound
mutators (``cow_partial`` -> ``share``/``prepare_write``, ``alloc`` ->
``pop_reclaimable``) attribute to the outermost public entry point, not
a mid-compound transient.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.analysis.differential import (diff_fingerprints, run_cross_mode,
                                         state_fingerprint)
from repro.analysis.hooks import (ALLOCATOR_HOOKS, CACHE_HOOKS,
                                  install_call_hooks)
from repro.analysis.invariants import InvariantViolation, verify_state
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.kv_cache import PageAllocator
from repro.core.prefix_cache import PrefixCache

PS = 4


def _pair(hooked=True):
    cache = PrefixCache(PS)
    alloc = PageAllocator(16, PS, cache=cache)
    hooks = install_call_hooks(alloc, cache) if hooked else None
    return alloc, cache, hooks


# ------------------------------------------- per-mutator attribution ----
def test_clean_lifecycle_passes_under_hooks():
    alloc, cache, hooks = _pair()
    pages = alloc.alloc(1, 3)
    cache.insert(list(range(2 * PS)), pages[:2])
    alloc.share(2, pages[:2])
    alloc.prepare_write(2, 2 * PS, 1)
    alloc.free(1)
    alloc.free(2)
    verify_state(alloc, cache)
    # every public mutator exercised above was checked at its exit
    assert hooks.n_call_checks >= 5
    for method in ("alloc", "share", "prepare_write", "free"):
        assert hooks.calls.get(method, 0) > 0, method


def test_alloc_attributed():
    alloc, cache, _ = _pair()
    pages = alloc.alloc(1, 2)
    alloc._ref[pages[0]] += 1            # inject: refcount without an owner
    with pytest.raises(InvariantViolation) as e:
        alloc.alloc(2, 1)
    assert e.value.invariant == "refcount_honesty"
    assert e.value.call_site["method"] == "alloc"
    assert e.value.call_site["rid"] == 2


def test_free_attributed():
    alloc, cache, _ = _pair()
    alloc.alloc(1, 2)
    alloc._free.pop()                    # inject: a page vanishes entirely
    with pytest.raises(InvariantViolation) as e:
        alloc.free(1)
    assert e.value.invariant == "page_conservation"
    assert e.value.call_site["method"] == "free"
    assert e.value.call_site["rid"] == 1


def test_share_outside_cache_contract_attributed():
    # genuine misuse, not a planted flag: sharing an uncached page makes
    # it multi-referenced with no COW guard — the hook catches the bad
    # call itself, at the call
    alloc, cache, _ = _pair()
    (page,) = alloc.alloc(1, 1)
    with pytest.raises(InvariantViolation) as e:
        alloc.share(2, [page])
    assert e.value.invariant == "cow_exclusivity"
    assert e.value.call_site["method"] == "share"
    assert str(page) in e.value.call_site["args"]


def test_prepare_write_attributed():
    alloc, cache, _ = _pair()
    (page,) = alloc.alloc(1, 1)
    cache.insert(list(range(PS)), [page])
    alloc.share(2, [page])
    alloc._owned[1].append(page)         # inject: duplicate mapping
    with pytest.raises(InvariantViolation) as e:
        alloc.prepare_write(2, 0, 1)
    assert e.value.invariant in ("refcount_honesty", "cow_exclusivity")
    assert e.value.call_site["method"] == "prepare_write"


def test_cow_partial_attributed_not_its_nested_calls():
    # cow_partial internally calls share() and prepare_write() — both
    # hooked.  The depth guard must attribute the violation to the
    # outermost public call, and must not false-positive on the
    # legitimately-inconsistent mid-compound states.
    alloc, cache, _ = _pair()
    (donor,) = alloc.alloc(1, 1)
    cache.insert(list(range(3)), [donor], allow_partial=True)
    alloc.free(1)                        # donor parks reclaimable
    alloc._free.append(99)               # inject: phantom page in the pool
    with pytest.raises(InvariantViolation) as e:
        alloc.cow_partial(2, donor)
    assert e.value.invariant == "page_conservation"
    assert e.value.call_site["method"] == "cow_partial"


def test_cow_partial_clean_counts_only_outer_call():
    alloc, cache, hooks = _pair()
    (donor,) = alloc.alloc(1, 1)
    cache.insert(list(range(3)), [donor], allow_partial=True)
    alloc.free(1)
    before_share = hooks.calls.get("share", 0)
    alloc.cow_partial(2, donor)
    # nested share/prepare_write ran but were not separately checked
    assert hooks.calls["cow_partial"] == 1
    assert hooks.calls.get("share", 0) == before_share


def test_insert_attributed():
    alloc, cache, _ = _pair()
    pages = alloc.alloc(1, 2)
    cache.insert(list(range(2 * PS)), pages)
    cache._by_page[pages[0]].n_desc += 1      # inject: descendant drift
    extra = alloc.alloc(2, 1)
    with pytest.raises(InvariantViolation) as e:
        cache.insert(list(range(100, 100 + PS)), extra)
    assert e.value.invariant == "trie_structure"
    assert e.value.call_site["method"] == "insert"


def test_pop_reclaimable_clean_exit_is_exempt():
    # the returned page is in the caller's hands — in no bucket — and
    # the hook must excuse exactly that page from conservation
    alloc, cache, hooks = _pair()
    (page,) = alloc.alloc(1, 1)
    cache.insert(list(range(PS)), [page])
    alloc.free(1)                        # parks reclaimable
    got = cache.pop_reclaimable()
    assert got == page
    assert hooks.calls["pop_reclaimable"] == 1   # checked, did not raise


def test_pop_reclaimable_attributed():
    alloc, cache, _ = _pair()
    (p1,) = alloc.alloc(1, 1)
    cache.insert(list(range(PS)), [p1])
    alloc.free(1)
    (p2,) = alloc.alloc(2, 1)
    cache.insert(list(range(50, 50 + PS)), [p2])
    alloc.free(2)
    cache._by_page[p2].reclaimable = False    # inject: pool/flag split
    with pytest.raises(InvariantViolation) as e:
        cache.pop_reclaimable()               # pops p1 (LRU), checks, sees p2
    assert e.value.invariant == "trie_structure"
    assert e.value.call_site["method"] == "pop_reclaimable"


def test_pop_blocked_attributed():
    alloc, cache, _ = _pair()
    pages = alloc.alloc(1, 2)
    cache.insert(list(range(2 * PS)), pages)
    alloc.share(2, [pages[1]])           # keep the child referenced
    alloc.free(1)                        # parent parks reclaimable, blocked
    cache._by_page[pages[0]].n_desc += 5      # inject: descendant drift
    with pytest.raises(InvariantViolation) as e:
        cache._pop_blocked(cache.default_policy)
    assert e.value.invariant == "trie_structure"
    assert e.value.call_site["method"] == "_pop_blocked"


def test_every_hooked_method_has_an_attribution_test():
    """Meta-check: the per-method tests above cover the full hook maps,
    so adding a mutator to hooks.py without a proof here fails."""
    proven = {"alloc", "free", "share", "prepare_write", "cow_partial",
              "insert", "pop_reclaimable", "_pop_blocked"}
    assert set(ALLOCATOR_HOOKS) | set(CACHE_HOOKS) == proven


def test_uninstall_restores_unhooked_behaviour():
    alloc, cache, hooks = _pair()
    hooks.uninstall()
    pages = alloc.alloc(1, 2)
    alloc._ref[pages[0]] += 1
    alloc.alloc(2, 1)                    # no hook: corruption sails through
    with pytest.raises(InvariantViolation):
        verify_state(alloc, cache)       # ...but the state checker still sees it


# ------------------------------------------------------ engine wiring ----
ARCH = "qwen3-0.6b"

SMALL = ServeConfig(max_batch=4, page_size=4, n_pages=20,
                    max_pages_per_seq=12, prefill_chunk=4, n_streams=2,
                    enable_prefix_cache=True, sanitize_level="call")


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    shared = list(rng.randint(2, model.cfg.vocab_size, size=8))
    prompts = [shared + list(rng.randint(2, model.cfg.vocab_size, size=4))
               for _ in range(4)]
    return model, params, prompts


def _requests(prompts, n_new=12):
    return [Request(rid=i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=n_new))
            for i, p in enumerate(prompts)]


@pytest.mark.parametrize("mode", ["sequential", "splitwiser", "splitwiser_mps"])
def test_clean_run_under_call_sanitizer(setup, mode):
    model, params, prompts = setup
    eng = Engine(model, params, dataclasses.replace(SMALL, mode=mode))
    m = eng.run(_requests(prompts), max_steps=4000)
    assert m.summary()["n_done"] == len(prompts)
    assert eng.sanitizer.n_call_checks > 0     # hooks actually ran
    assert eng.sanitizer.n_checks > 0          # step tier still active


def test_call_level_streams_match_off(setup):
    model, params, prompts = setup
    outs = {}
    for level in ("off", "call"):
        eng = Engine(model, params,
                     dataclasses.replace(SMALL, sanitize_level=level))
        reqs = _requests(prompts)
        eng.run(reqs, max_steps=4000)
        outs[level] = [r.out_tokens for r in reqs]
    assert outs["off"] == outs["call"]         # checks are read-only


def test_engine_corruption_attributed_to_call(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SMALL)
    for r in _requests(prompts):
        eng.submit(r)
    eng.step()
    live = [rid for rid, pages in eng.alloc._owned.items() if pages]
    eng.alloc._ref[eng.alloc._owned[live[0]][0]] += 1    # inject mid-run
    with pytest.raises(InvariantViolation) as e:
        eng.alloc.alloc(999, 1)            # engine allocator is hooked
    assert e.value.invariant == "refcount_honesty"
    assert e.value.call_site["method"] == "alloc"
    # engine context rides along: event tail + engine state in the dump
    assert "engine" in e.value.state
    assert any(ev.get("event") == "admit" for ev in e.value.events)


# ------------------------------------------- cross-mode differential ----
def test_state_fingerprint_detects_drift():
    alloc_a, cache_a, _ = _pair(hooked=False)
    alloc_b, cache_b, _ = _pair(hooked=False)
    for alloc, cache in ((alloc_a, cache_a), (alloc_b, cache_b)):
        pages = alloc.alloc(1, 2)
        cache.insert(list(range(2 * PS)), pages)
        alloc.free(1)
    assert diff_fingerprints(state_fingerprint(alloc_a),
                             state_fingerprint(alloc_b)) == []
    # b caches one extra chain -> reported by token path, not page id
    (extra,) = alloc_b.alloc(2, 1)
    cache_b.insert(list(range(70, 70 + PS)), [extra])
    alloc_b.free(2)
    diffs = diff_fingerprints(state_fingerprint(alloc_a),
                              state_fingerprint(alloc_b),
                              label_a="sequential", label_b="splitwiser")
    assert diffs and any("only in splitwiser" in d for d in diffs)


def test_cross_mode_differential_state_identical(setup):
    """Same workload, ample pool: all three modes must leave *identical*
    final allocator/cache state (by token path), not just identical
    token streams."""
    model, params, prompts = setup
    roomy = dataclasses.replace(SMALL, n_pages=96, sanitize_level="step")

    report = run_cross_mode(
        lambda mode: Engine(model, params,
                            dataclasses.replace(roomy, mode=mode)),
        lambda: _requests(prompts, n_new=8),
        modes=("sequential", "splitwiser", "splitwiser_mps"),
        max_steps=4000)
    assert report["streams_match"]
    assert all(d == [] for d in report["state_diffs"].values()), \
        report["state_diffs"]
    # and the fingerprints are non-trivial (the workload cached chains)
    assert report["fingerprints"]["sequential"]["chains"]
