"""Chunked-prefill mixed-dispatch subsystem (``mode="chunked"``).

The contract under test, at three layers:

* **Planner** (pure): decodes pack unconditionally, prefill chunks fill
  the leftover budget work-conservingly, the rotating cursor keeps tight
  budgets fair, and :func:`validate_plan` rejects every way a plan can
  break the packing contract (mutation-style, so the sanitizer's
  ``chunk_plan`` invariant is live, not vacuous).
* **Engine**: chunked greedy streams are bit-identical to the sequential
  oracle across the full ``admission x eviction x preempt`` policy
  matrix (randomized interleavings of chunk boundary x partial-prefix
  hit x preemption/resume live in ``test_chunked_properties.py``, which
  needs hypothesis).  Final allocator/cache state fingerprints match
  the monolithic modes exactly, and the pressured run drives real
  preemptions through the differential preempt/resume checker.
* **Scheduler/metrics**: admission charges one chunk's pages instead of
  the whole prompt; live decodes ride in *every* round while a long
  prompt prefills (the tail-TBT property, asserted on the event stream);
  ``n_chunks`` / occupancy / packed-token histogram surface in summary().
"""
import dataclasses
import itertools

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.analysis.differential import run_cross_mode
from repro.analysis.invariants import InvariantViolation
from repro.configs import ServeConfig
from repro.configs.base import SERVE_MODES
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.planner import ChunkPlan, ChunkPlanner, validate_plan

ARCH = "qwen3-0.6b"
PS = 4
N_NEW = 8
BASE = ServeConfig(mode="chunked", max_batch=3, page_size=PS, n_pages=26,
                   max_pages_per_seq=12, prefill_chunk=PS, n_streams=2,
                   chunk_tokens=8, enable_prefix_cache=True)


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _workload(vocab, seed=0):
    """test_policies' pressured shared-prefix workload: adjacent twins
    (same-round identical prefixes) plus a unique prompt."""
    rng = np.random.RandomState(seed)
    a = list(rng.randint(2, vocab, size=12))
    b = list(rng.randint(2, vocab, size=12))
    prompts = [a + [11, 12], a + [13, 14], b + [15, 16], b + [17, 18],
               list(rng.randint(2, vocab, size=14))]
    return [Request(rid=i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=N_NEW))
            for i, p in enumerate(prompts)]


@pytest.fixture(scope="module")
def oracle(setup):
    """Cache-off, generous-pool sequential greedy reference."""
    model, params = setup
    serve = dataclasses.replace(BASE, mode="sequential", n_pages=128,
                                enable_prefix_cache=False)
    reqs = _workload(model.cfg.vocab_size)
    Engine(model, params, serve).run(reqs, max_steps=4000)
    return [r.out_tokens for r in reqs]


# ================================================== planner unit tests ====
def test_decodes_claim_budget_first():
    p = ChunkPlanner(chunk_tokens=8, n_streams=2)
    plan = p.plan([100, 100], n_decode_tokens=3)
    assert plan.n_decode_tokens == 3
    assert plan.n_prefill_tokens == 5          # 8 - 3 left for prefill
    assert plan.n_packed_tokens == 8
    assert plan.occupancy == 1.0


def test_decode_batch_alone_may_exceed_budget():
    """Decodes are never dropped to fit: a decode batch bigger than the
    budget packs whole and prefill gets nothing."""
    p = ChunkPlanner(chunk_tokens=4, n_streams=2)
    plan = p.plan([50, 50], n_decode_tokens=6)
    assert plan.chunk_lens == (0, 0)
    assert plan.n_packed_tokens == 6
    assert plan.occupancy > 1.0


def test_carve_is_work_conserving():
    p = ChunkPlanner(chunk_tokens=16, n_streams=3)
    plan = p.plan([2, 0, 3], n_decode_tokens=0)
    assert plan.chunk_lens == (2, 0, 3)        # everything available taken
    validate_plan(plan, [2, 0, 3], 0)


def test_cursor_rotates_for_fairness():
    """Budget too small for both streams: the passed-over stream goes
    first next round instead of starving behind a long prompt."""
    p = ChunkPlanner(chunk_tokens=4, n_streams=2)
    assert p.plan([100, 100], 0).chunk_lens == (4, 0)
    assert p.plan([100, 100], 0).chunk_lens == (0, 4)
    assert p.plan([100, 100], 0).chunk_lens == (4, 0)


def test_planner_ctor_validates():
    with pytest.raises(ValueError, match="chunk_tokens"):
        ChunkPlanner(0, 2)
    with pytest.raises(ValueError, match="n_streams"):
        ChunkPlanner(8, 0)


def test_plan_inputs_validated():
    p = ChunkPlanner(8, 2)
    with pytest.raises(ValueError, match="stream remainders"):
        p.plan([1, 2, 3], 0)
    with pytest.raises(ValueError, match="n_decode_tokens"):
        p.plan([1, 2], -1)


# Mutation-style proofs that every clause of the packing contract is
# enforced — if a validate_plan check regresses to a no-op, its test fails.
@pytest.mark.parametrize("plan,remaining,n_decode,msg", [
    (ChunkPlan((4, 0), 1, 8, 8), [10, 10], 2, "unconditionally"),
    (ChunkPlan((5, 0), 0, 8, 8), [3, 10], 0, "remaining prefill"),
    (ChunkPlan((-1, 0), 0, 8, 8), [3, 10], 0, "negative"),
    (ChunkPlan((4, 4), 2, 8, 8), [10, 10], 2, "budget"),
    (ChunkPlan((9, 0), 0, 8, 4), [10, 0], 0, "cap"),
    (ChunkPlan((2, 0), 0, 8, 8), [2, 10], 0, "work-conserving"),
    (ChunkPlan((4,), 0, 8, 8), [10, 10], 0, "streams"),
])
def test_validate_plan_rejects_contract_breaks(plan, remaining, n_decode, msg):
    with pytest.raises(ValueError, match=msg):
        validate_plan(plan, remaining, n_decode)


# ===================================================== config plumbing ====
def test_chunked_registered_and_knob_validated():
    assert "chunked" in SERVE_MODES
    ServeConfig(mode="chunked", chunk_tokens=16, page_size=16)  # fine
    with pytest.raises(ValueError, match="chunk_tokens"):
        ServeConfig(chunk_tokens=0)
    with pytest.raises(ValueError, match="page_size"):
        ServeConfig(chunk_tokens=8, page_size=16)


def test_unknown_mode_fails_loud(setup):
    """A mode registered in SERVE_MODES without a step path must raise,
    not silently no-op (step() holds the only mode dispatch)."""
    model, params = setup
    eng = Engine(model, params, dataclasses.replace(BASE, mode="sequential"))
    object.__setattr__(eng.serve, "mode", "time_warp")
    with pytest.raises(RuntimeError, match="no step path"):
        eng.step()


# ============================================= stream-level equivalence ====
def test_greedy_bit_identical_across_policy_matrix(setup, oracle):
    """Chunking changes *when* prompt tokens are prefilled, never *what*
    is generated: oracle-exact under every policy combination, with the
    pool fully drained."""
    model, params = setup
    matrix = list(itertools.product(("fcfs", "cache_aware"),
                                    ("lru", "fifo", "cost"),
                                    ("latest", "cache_aware")))
    for adm, ev, pre in matrix:
        serve = dataclasses.replace(BASE, admission_policy=adm,
                                    eviction_policy=ev, preempt_policy=pre)
        eng = Engine(model, params, serve)
        reqs = _workload(model.cfg.vocab_size)
        s = eng.run(reqs, max_steps=8000).summary()
        assert s["n_done"] == len(reqs), (adm, ev, pre)
        assert [r.out_tokens for r in reqs] == oracle, (adm, ev, pre)
        assert eng.alloc.n_allocated == 0 and eng.idle()


def test_cross_mode_state_fingerprints_identical(setup):
    """Ample pool: chunked leaves byte-for-byte the same final
    allocator/cache state (by token path) as both monolithic modes."""
    model, params = setup
    roomy = dataclasses.replace(BASE, n_pages=96, sanitize_level="step")
    report = run_cross_mode(
        lambda mode: Engine(model, params,
                            dataclasses.replace(roomy, mode=mode)),
        lambda: _workload(model.cfg.vocab_size),
        modes=("sequential", "splitwiser", "chunked"),
        max_steps=8000)
    assert report["streams_match"]
    assert all(d == [] for d in report["state_diffs"].values()), \
        report["state_diffs"]
    assert report["fingerprints"]["chunked"]["chains"]


def test_pressured_run_exercises_preempt_promises(setup, oracle):
    """A pool tight enough to actually preempt mid-prompt (per-chunk
    admission packs more requests in than monolithic budgeting, so it
    takes a smaller pool than the matrix test's 26 pages): resume
    re-enters mid-chunk via the committed pages — audited by the
    differential preempt/resume checker (step sanitizer), which stayed
    silent."""
    model, params = setup
    eng = Engine(model, params,
                 dataclasses.replace(BASE, n_pages=18,
                                     sanitize_level="step"))
    reqs = _workload(model.cfg.vocab_size)
    m = eng.run(reqs, max_steps=8000)
    assert m.summary()["n_done"] == len(reqs)
    assert m.n_preempt_events > 0            # the checker had work to do
    assert not eng.sanitizer._preempt_snaps  # every promise was settled
    assert [r.out_tokens for r in reqs] == oracle


# =============================================== scheduler + sanitizer ====
def test_admission_charges_per_chunk_not_whole_prompt(setup):
    """A 64-token prompt: monolithic admission budgets ~17 pages up
    front; chunked admission budgets one chunk (+decode headroom) and
    grows the budget per scheduled chunk."""
    model, params = setup
    req = Request(rid=0, prompt=list(range(2, 66)),
                  sampling=SamplingParams(max_new_tokens=4))
    roomy = dataclasses.replace(BASE, n_pages=64, max_pages_per_seq=32)
    chunked_need = Engine(model, params, roomy).sched.admission_pages(req)
    seq_need = Engine(
        model, params, dataclasses.replace(roomy, mode="sequential"),
    ).sched.admission_pages(req)
    assert chunked_need < seq_need
    # the chunk charge covers the budget's worth of tokens, nothing more
    assert chunked_need <= (BASE.chunk_tokens // PS) + 2


def test_sanitizer_flags_contract_breaking_plan(setup):
    """Wiring proof for the ``chunk_plan`` invariant: a planner that
    drops a decode token is caught at the very next step."""
    model, params = setup

    class _DropsDecodes:
        def plan(self, remaining, n_decode_tokens, priorities=None):
            return ChunkPlan(tuple(0 for _ in remaining),
                             max(n_decode_tokens - 1, 0),
                             BASE.chunk_tokens, BASE.chunk_tokens)

    eng = Engine(model, params,
                 dataclasses.replace(BASE, sanitize_level="step"))
    eng.planner = _DropsDecodes()
    with pytest.raises(InvariantViolation) as e:
        eng.run(_workload(model.cfg.vocab_size), max_steps=8000)
    assert e.value.invariant == "chunk_plan"


# =========================================== tail-TBT property + metrics ====
def test_decodes_ride_every_round_during_long_prefill(setup):
    """The property the subsystem exists for: while a long prompt
    prefills chunk by chunk, an in-flight decode emits a token on every
    single round — under splitwiser the same scenario has whole rounds
    with no decode event (the phase-exclusive prefill steps)."""
    model, params = setup
    rng = np.random.RandomState(3)
    vocab = model.cfg.vocab_size
    short = list(rng.randint(2, vocab, size=4))
    long_p = list(rng.randint(2, vocab, size=48))

    def starved_rounds(mode):
        serve = dataclasses.replace(BASE, mode=mode, n_pages=96,
                                    max_pages_per_seq=24)
        eng = Engine(model, params, serve)
        sr = Request(rid=0, prompt=list(short),
                     sampling=SamplingParams(max_new_tokens=16))
        eng.submit(sr)
        while not sr.out_tokens:             # short request mid-decode...
            eng.step()
        eng.submit(Request(rid=1, prompt=list(long_p),   # ...enter the
                           sampling=SamplingParams(max_new_tokens=2)))
        starved = 0
        while len(sr.out_tokens) < 16:
            evs = eng.step()
            if not any(ev.rid == sr.rid for ev in evs):
                starved += 1
        while not eng.idle():
            eng.step()
        return starved

    assert starved_rounds("chunked") == 0
    assert starved_rounds("splitwiser") > 0


def test_chunk_metrics_surface_in_summary(setup):
    model, params = setup
    eng = Engine(model, params, dataclasses.replace(BASE, n_pages=96))
    s = eng.run(_workload(model.cfg.vocab_size), max_steps=8000).summary()
    assert s["n_chunks"] > 0
    assert 0.0 < s["chunk_occupancy"] <= 2.0
    hist = s["packed_tokens_hist"]
    assert hist and all(k > 0 and v > 0 for k, v in hist.items())
    # one histogram entry per mixed round, each within budget + decodes
    assert sum(hist.values()) == eng.metrics.step_kinds.count("mixed")
    assert max(hist) <= BASE.chunk_tokens + BASE.max_batch
