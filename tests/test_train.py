"""Training substrate: loss decreases, microbatch==full-batch equivalence,
chunked loss == full loss, bitwise crash+resume, int8-moment accuracy,
elastic TP re-layout."""
import os
import shutil

import jax
import numpy as np
import pytest

from conftest import family_batch, reduced_model
from repro.configs import TrainConfig
from repro.data import make_train_data_fn
from repro.train.losses import lm_loss, lm_loss_from_hidden
from repro.train.trainer import Trainer, init_state, make_train_step


def test_loss_decreases_qwen():
    model = reduced_model("qwen3-0.6b")
    tcfg = TrainConfig(global_batch=8, seq_len=32, total_steps=40, lr=5e-3,
                       warmup_steps=5, ckpt_dir="/tmp/repro_t1", remat=True)
    shutil.rmtree(tcfg.ckpt_dir, ignore_errors=True)
    tr = Trainer(model, tcfg, make_train_data_fn(model.cfg, tcfg), log_every=5)
    hist = tr.run()
    losses = [l for _, l in hist]
    assert losses[-1] < losses[0] - 0.1, losses


def test_chunked_loss_equals_full():
    model = reduced_model("qwen3-0.6b")
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    from repro.models import transformer as T
    batch = family_batch(cfg, 2, 20)
    labels = jax.random.randint(jax.random.PRNGKey(9), (2, 20), 0,
                                cfg.vocab_size)
    labels = labels.at[0, :5].set(-100)
    hidden, _ = T.train_hidden(params, cfg, batch)
    table = params["embed"]
    l1, n1 = lm_loss_from_hidden(hidden, labels, table, chunk=7,
                                 v_real=cfg.vocab_size)
    logits = T.unembed(params, cfg, hidden)
    l2, n2 = lm_loss(logits, labels, v_real=cfg.vocab_size)
    assert abs(float(l1) - float(l2)) < 1e-4
    assert float(n1) == float(n2)


def test_microbatch_matches_full_batch():
    model = reduced_model("qwen3-0.6b")
    cfg = model.cfg
    t_full = TrainConfig(global_batch=4, seq_len=16, total_steps=1,
                         ckpt_dir="/tmp/x", remat=False, grad_clip=1e9)
    t_micro = TrainConfig(global_batch=4, seq_len=16, total_steps=1,
                          microbatch=2, ckpt_dir="/tmp/x", remat=False,
                          grad_clip=1e9)
    batch = family_batch(cfg, 4, 16)
    batch["labels"] = batch["tokens"]
    s1 = init_state(model, jax.random.PRNGKey(0), t_full)
    s2 = init_state(model, jax.random.PRNGKey(0), t_micro)
    s1, m1 = jax.jit(make_train_step(model, t_full))(s1, batch)
    s2, m2 = jax.jit(make_train_step(model, t_micro))(s2, batch)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"]), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_crash_resume_bitwise():
    model = reduced_model("qwen3-0.6b")
    tcfg = TrainConfig(global_batch=4, seq_len=16, total_steps=20,
                       ckpt_every=5, ckpt_dir="/tmp/repro_t2", remat=False)
    data_fn = make_train_data_fn(model.cfg, tcfg)
    shutil.rmtree(tcfg.ckpt_dir, ignore_errors=True)
    ref = Trainer(model, tcfg, data_fn)
    ref.run()
    p_ref = jax.tree.leaves(jax.tree.map(np.asarray, ref.state["params"]))

    shutil.rmtree(tcfg.ckpt_dir, ignore_errors=True)
    t1 = Trainer(model, tcfg, data_fn)
    with pytest.raises(RuntimeError):
        t1.run(crash_at=12)
    t2 = Trainer(model, tcfg, data_fn)        # auto-resume from step 10
    assert t2.start_step == 10
    t2.run()
    p_res = jax.tree.leaves(jax.tree.map(np.asarray, t2.state["params"]))
    for a, b in zip(p_ref, p_res, strict=True):
        assert np.array_equal(a, b)


def test_int8_moments_track_fp32():
    model = reduced_model("qwen3-0.6b")
    cfg = model.cfg
    t8 = TrainConfig(global_batch=4, seq_len=16, total_steps=5,
                     int8_moments=True, ckpt_dir="/tmp/x", remat=False)
    tf = TrainConfig(global_batch=4, seq_len=16, total_steps=5,
                     int8_moments=False, ckpt_dir="/tmp/x", remat=False)
    data_fn = make_train_data_fn(cfg, t8)
    s8 = init_state(model, jax.random.PRNGKey(0), t8)
    sf = init_state(model, jax.random.PRNGKey(0), tf)
    f8 = jax.jit(make_train_step(model, t8))
    ff = jax.jit(make_train_step(model, tf))
    for i in range(5):
        b = data_fn(i)
        b["labels"] = b["tokens"]
        s8, m8 = f8(s8, b)
        sf, mf = ff(sf, b)
    # losses should stay close (quantization noise only)
    assert abs(float(m8["loss"]) - float(mf["loss"])) < 0.1


def test_elastic_relayout_preserves_function():
    """Checkpoint trained at tp=1 re-laid-out to tp=8 must compute the
    same function (padded heads inert)."""
    from repro.ckpt.checkpoint import relayout_attention_params
    from repro.models import transformer as T
    model = reduced_model("gemma2-2b")     # H=4? reduced: n_heads<=4, kv<=2
    cfg = model.cfg
    p1 = model.init(jax.random.PRNGKey(0), tp=1)
    batch = family_batch(cfg, 2, 12)
    l1, _ = T.train_logits(p1, cfg, batch, tp=1)
    p8 = relayout_attention_params(p1, cfg, tp_from=1, tp_to=8)
    l8, _ = T.train_logits(p8, cfg, batch, tp=8)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l8), atol=1e-4)


def test_checkpoint_roundtrip_structure():
    from repro.ckpt.checkpoint import latest_step, load, save
    model = reduced_model("olmoe-1b-7b")
    tcfg = TrainConfig(global_batch=2, seq_len=8, total_steps=1,
                       ckpt_dir="/tmp/repro_t3")
    shutil.rmtree(tcfg.ckpt_dir, ignore_errors=True)
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    save(tcfg.ckpt_dir, 7, state)
    assert latest_step(tcfg.ckpt_dir) == 7
    back = load(tcfg.ckpt_dir, 7)
    for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray, state)),
                    jax.tree.leaves(back), strict=True):
        assert np.array_equal(np.asarray(a), b)
