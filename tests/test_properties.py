"""Hypothesis property tests on system invariants: page allocator,
scheduler conservation, sampler, SSM chunk-invariance, quantized moments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.kv_cache import PageAllocator


# ------------------------------------------------------------- allocator ---
@settings(max_examples=50, deadline=None)
@given(st.data())
def test_allocator_never_double_allocates(data):
    n_pages = data.draw(st.integers(8, 128))
    ps = data.draw(st.integers(1, 32))
    alloc = PageAllocator(n_pages, ps)
    live = {}
    for step in range(data.draw(st.integers(1, 40))):
        if live and data.draw(st.booleans()):
            rid = data.draw(st.sampled_from(sorted(live)))
            alloc.free(rid)
            del live[rid]
        else:
            rid = step + 1000
            n = data.draw(st.integers(1, 8))
            if alloc.can_alloc(n):
                pages = alloc.alloc(rid, n)
                assert len(pages) == n
                assert alloc.trash_page not in pages
                live[rid] = pages
        # invariant: all live pages disjoint
        flat = [p for ps_ in live.values() for p in ps_]
        assert len(flat) == len(set(flat))
        assert 0.0 <= alloc.usage() <= 1.0
        assert alloc.n_allocated == len(flat)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(1, 16), st.integers(1, 200))
def test_allocator_free_returns_everything(n_pages, ps, tokens):
    alloc = PageAllocator(n_pages, ps)
    need = alloc.pages_needed(tokens)
    assert need == -(-tokens // ps)
    if need <= alloc.n_free:
        alloc.alloc(1, need)
        extra = alloc.extend_to(1, tokens)       # already enough
        assert extra == []
        alloc.free(1)
    assert alloc.n_free == n_pages - 1
    assert alloc.n_allocated == 0


# --------------------------------------------------------------- sampler ---
def _params_rows(B, *, temperature=0.0, top_k=0, top_p=1.0, seed=0, pos=0):
    """Uniform per-row parameter arrays for sample_tokens."""
    return (jnp.full((B,), temperature, jnp.float32),
            jnp.full((B,), top_k, jnp.int32),
            jnp.full((B,), top_p, jnp.float32),
            jnp.full((B,), seed, jnp.int32),
            jnp.arange(B, dtype=jnp.int32),          # rid
            jnp.full((B,), pos, jnp.int32))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(2, 50))
def test_sampler_greedy_is_argmax(seed, B, V):
    from repro.core.sampler import sample_tokens
    logits = jax.random.normal(jax.random.PRNGKey(seed), (B, V))
    toks = sample_tokens(logits, *_params_rows(B))
    assert (np.asarray(toks) == np.asarray(logits.argmax(-1))).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_sampler_topk_support(seed):
    from repro.core.sampler import sample_tokens
    logits = jax.random.normal(jax.random.PRNGKey(seed), (4, 64))
    k = 5
    toks = np.asarray(sample_tokens(
        logits, *_params_rows(4, temperature=1.0, top_k=k, seed=seed)))
    topk = np.asarray(jax.lax.top_k(logits, k)[1])
    for b in range(4):
        assert toks[b] in topk[b]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 5))
def test_sampler_rows_are_independent(seed, B):
    """A row's token depends only on its own (logits, params, rid, pos)
    triple — never on what else is in the batch."""
    from repro.core.sampler import sample_tokens
    logits = jax.random.normal(jax.random.PRNGKey(seed), (B, 32))
    temp, tk, tp, sd, rid, pos = _params_rows(B, temperature=0.9, seed=seed)
    full = np.asarray(sample_tokens(logits, temp, tk, tp, sd, rid, pos))
    for b in range(B):
        alone = np.asarray(sample_tokens(
            logits[b:b + 1], temp[b:b + 1], tk[b:b + 1], tp[b:b + 1],
            sd[b:b + 1], rid[b:b + 1], pos[b:b + 1]))
        assert alone[0] == full[b]


# --------------------------------------------------- scheduler conservation
@settings(max_examples=8, deadline=None)
@given(st.data())
def test_engine_conserves_requests(data):
    from conftest import reduced_model
    from repro.configs import ServeConfig
    from repro.core.engine import Engine, Request, SamplingParams
    model = reduced_model("qwen3-0.6b")
    mode = data.draw(st.sampled_from(
        ["sequential", "splitwiser", "splitwiser_mps"]))
    kv_dtype = data.draw(st.sampled_from(["fp", "int8"]))
    n_req = data.draw(st.integers(1, 5))
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(mode=mode, max_batch=3, page_size=4, n_pages=96,
                        max_pages_per_seq=12, prefill_chunk=4, n_streams=2,
                        kv_dtype=kv_dtype)
    eng = Engine(model, params, serve)
    rng = np.random.RandomState(data.draw(st.integers(0, 100)))
    reqs = [Request(rid=i, prompt=list(rng.randint(2, 200, rng.randint(3, 12))),
                    sampling=SamplingParams(max_new_tokens=int(rng.randint(1, 6))))
            for i in range(n_req)]
    m = eng.run(reqs, max_steps=2000)
    s = m.summary()
    assert s["n_done"] == n_req                      # nothing lost or stuck
    for r in reqs:
        assert len(r.out_tokens) == r.max_new_tokens  # exact budget
    assert eng.alloc.n_allocated == 0                # all pages returned
    assert eng.idle()


# ---------------------------------------------------------- SSM invariance
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 3), st.integers(2, 20),
       st.sampled_from([1, 2, 3, 5, 8]))
def test_rwkv_chunk_size_invariance(seed, B, T, chunk):
    """Output must not depend on the chunking of the scan."""
    from repro.configs import get_config
    from repro.models import ssm
    cfg = get_config("rwkv6-7b").reduced()
    lp = ssm.rwkv6_init(jax.random.PRNGKey(seed % 7), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, T, cfg.d_model)) * 0.3
    st0 = {k: jnp.zeros(v) for k, v in ssm.rwkv6_state_shapes(cfg, B).items()}
    y1, s1 = ssm.rwkv6_layer(lp, cfg, x, st0, chunk=chunk)
    y2, s2 = ssm.rwkv6_layer(lp, cfg, x, st0, chunk=T)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1["S"]), np.asarray(s2["S"]),
                               atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 16), st.sampled_from([1, 2, 4, 7]))
def test_mamba_chunk_size_invariance(seed, T, chunk):
    from repro.configs import get_config
    from repro.models import ssm
    cfg = get_config("zamba2-7b").reduced()
    lp = ssm.mamba2_init(jax.random.PRNGKey(seed % 5), cfg, jnp.float32)
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, T, cfg.d_model)) * 0.3
    cs, ss = ssm.mamba2_state_shapes(cfg, B)
    c0 = {k: jnp.zeros(v) for k, v in cs.items()}
    s0 = jnp.zeros(ss)
    y1, _, h1 = ssm.mamba2_block(lp, cfg, x, c0, s0, chunk=chunk)
    y2, _, h2 = ssm.mamba2_block(lp, cfg, x, c0, s0, chunk=T)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


# --------------------------------------------------------- int8 moments ---
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_q8_roundtrip_error_bounded(seed):
    from repro.optim.adamw import QBLOCK, _q8_decode, _q8_encode
    n = 1000
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    q, s = _q8_encode(jnp.asarray(x))
    back = np.asarray(_q8_decode(q, s, (n,)))
    pad = (-n) % QBLOCK
    err = np.pad(np.abs(back - x), (0, pad)).reshape(-1, QBLOCK)
    scales = np.asarray(s).reshape(-1)
    for i in range(len(scales)):
        # quantization error bounded by half a code step per block
        assert (err[i] <= scales[i] * 0.5 + 1e-9).all()
