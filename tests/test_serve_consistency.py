"""Serve-path equivalences: prefill/decode/mixed must match the full
forward oracle for every family (the system's core correctness invariant:
paged KV + chunked prefill + recurrent states are exact, not approximate).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.models import encdec, hybrid, rwkv
from repro.models import transformer as T


def _pool_from_prefill(k, v, ps, extra=6):
    kpg = T.kv_to_pages(k, ps)
    vpg = T.kv_to_pages(v, ps)
    L, N0 = kpg.shape[:2]
    pad = jnp.zeros((L, extra) + kpg.shape[2:], kpg.dtype)
    return jnp.concatenate([kpg, pad], 1), jnp.concatenate([vpg, pad], 1), N0


def _tables(B, S, ps, N0, width=8):
    per = S // ps
    bt = np.zeros((B, width), np.int32)
    for b in range(B):
        bt[b, :per] = np.arange(per) + b * per
        bt[b, per] = N0 + b
    return jnp.asarray(bt)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-2b", "olmoe-1b-7b",
                                  "starcoder2-3b", "internvl2-2b"])
def test_decode_matches_full_forward(arch):
    model = reduced_model(arch)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    B, S, ps = 2, 16, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    patches = None
    if cfg.family == "vlm":
        patches = jax.random.normal(jax.random.PRNGKey(2),
                                    (B, cfg.n_vision_patches, cfg.d_vision)) * 0.3
    last, (k, v) = T.prefill(params, cfg, toks, patches=patches)
    kpg, vpg, N0 = _pool_from_prefill(k, v, ps)
    S_tot = k.shape[2]
    bt = _tables(B, S_tot, ps, N0)
    lens = jnp.full((B,), S_tot, jnp.int32)
    nxt = last.argmax(-1).astype(jnp.int32)
    dl, _ = T.decode(params, cfg, nxt, kpg, vpg, bt, lens)
    batch = {"tokens": jnp.concatenate([toks, nxt[:, None]], 1)}
    if patches is not None:
        batch["patches"] = patches
    fl, _ = T.train_logits(params, cfg, batch)
    err = float(jnp.abs(dl - fl[:, -1]).max())
    assert err < 2e-3, (arch, err)


def test_mixed_chunked_prefill_matches_full():
    model = reduced_model("qwen3-0.6b")
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    B, S, ps, C = 1, 16, 4, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_last, _ = T.prefill(params, cfg, toks)
    kpg, vpg = T.init_pages(cfg, 16, ps)
    bt = jnp.asarray([[0, 1, 2, 3, 4, 5]], jnp.int32)
    out = None
    for i in range(S // C):
        mb = dict(p_tokens=toks[:, i * C:(i + 1) * C], p_table=bt,
                  p_start=jnp.asarray([i * C], jnp.int32),
                  p_lens=jnp.asarray([C], jnp.int32),
                  d_tokens=jnp.zeros((2,), jnp.int32),
                  d_table=jnp.zeros((2, 6), jnp.int32),
                  d_lens=jnp.zeros((2,), jnp.int32),
                  d_active=jnp.zeros((2,), bool))
        out, _, (kpg, vpg), _ = T.mixed(params, cfg, mb, kpg, vpg)
    err = float(jnp.abs(out[0] - full_last[0]).max())
    assert err < 2e-3, err


def test_encdec_decode_matches_full():
    model = reduced_model("seamless-m4t-medium")
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    B, S, ps = 2, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, 12, cfg.d_model)) * 0.3
    last, (k, v), (xk, xv) = encdec.prefill(params, cfg, frames, toks)
    kpg, vpg, N0 = _pool_from_prefill(k, v, ps)
    bt = _tables(B, S, ps, N0)
    nxt = last.argmax(-1).astype(jnp.int32)
    dl, _ = encdec.decode(params, cfg, nxt, kpg, vpg, xk, xv, bt,
                          jnp.full((B,), S, jnp.int32))
    fl, _ = encdec.train_logits(params, cfg, {
        "frames": frames, "tokens": jnp.concatenate([toks, nxt[:, None]], 1)})
    assert float(jnp.abs(dl - fl[:, -1]).max()) < 2e-3


def test_hybrid_decode_matches_full():
    model = reduced_model("zamba2-7b")
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    B, S, ps = 2, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    last, (k, v), (conv, sst) = hybrid.prefill(params, cfg, toks)
    kpg, vpg, N0 = _pool_from_prefill(k, v, ps)
    bt = _tables(B, S, ps, N0)
    nxt = last.argmax(-1).astype(jnp.int32)
    dl, _, _ = hybrid.decode(params, cfg, nxt, conv, sst, kpg, vpg, bt,
                             jnp.full((B,), S, jnp.int32))
    fl, _ = hybrid.train_logits(params, cfg, {
        "tokens": jnp.concatenate([toks, nxt[:, None]], 1)})
    assert float(jnp.abs(dl - fl[:, -1]).max()) < 2e-3


def test_rwkv_decode_matches_full():
    model = reduced_model("rwkv6-7b")
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    last, st = rwkv.prefill(params, cfg, toks)
    nxt = last.argmax(-1).astype(jnp.int32)
    dl, st = rwkv.decode(params, cfg, nxt, st)
    fl, _ = rwkv.train_logits(params, cfg, {
        "tokens": jnp.concatenate([toks, nxt[:, None]], 1)})
    assert float(jnp.abs(dl - fl[:, -1]).max()) < 2e-3


def test_gemma2_sliding_window_masks_old_tokens():
    """Local layers must not attend beyond the window."""
    from repro.models.layers import flash_attention
    B, T, H, d = 1, 12, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, d))
    pos = jnp.arange(T)[None]
    o_w = flash_attention(q, k, v, q_positions=pos, kv_positions=pos,
                          scale=1.0, window=4, block_kv=4)
    # perturb a kv pair far outside every query's window: position 0 vs
    # query at position 11 (window 4)
    k2 = k.at[:, 0].add(10.0)
    v2 = v.at[:, 0].add(10.0)
    o_w2 = flash_attention(q, k2, v2, q_positions=pos, kv_positions=pos,
                           scale=1.0, window=4, block_kv=4)
    assert jnp.allclose(o_w[:, 11], o_w2[:, 11], atol=1e-5)
    assert not jnp.allclose(o_w[:, 2], o_w2[:, 2], atol=1e-5)
