"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracle (ref.py), executed with interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk_paged(key, B, Tq, H, KV, d, ps, N, Pmax, dtype):
    ks = jax.random.split(jax.random.PRNGKey(key), 8)
    q = (jax.random.normal(ks[0], (B, Tq, H, d)) * 0.5).astype(dtype)
    kpg = (jax.random.normal(ks[1], (N, ps, KV, d)) * 0.5).astype(dtype)
    vpg = (jax.random.normal(ks[2], (N, ps, KV, d)) * 0.5).astype(dtype)
    perm = np.random.RandomState(key).permutation(N - 1)
    bt = jnp.asarray(perm[: B * Pmax].reshape(B, Pmax), jnp.int32)
    return q, kpg, vpg, bt


PAGED_CASES = [
    # B, Tq, H, KV, d, ps, N, Pmax
    (3, 1, 4, 2, 64, 8, 16, 4),        # decode GQA
    (2, 1, 8, 8, 128, 16, 32, 3),      # decode MHA, 128-dim
    (2, 8, 4, 4, 64, 8, 16, 4),        # chunked prefill
    (1, 16, 6, 2, 32, 4, 32, 8),       # chunk, d=32 (padded to lane)
    (2, 4, 4, 4, 112, 8, 16, 4),       # zamba head_dim=112 (lane pad)
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_vs_ref(case, dtype):
    B, Tq, H, KV, d, ps, N, Pmax = case
    q, kpg, vpg, bt = _mk_paged(0, B, Tq, H, KV, d, ps, N, Pmax, dtype)
    hist = np.random.RandomState(1).randint(0, Pmax * ps - Tq, size=B)
    q_pos = jnp.asarray(hist, jnp.int32)
    kv_lens = q_pos + Tq
    out = ops.paged_attention(q, kpg, vpg, bt, kv_lens, q_pos, scale=0.2)
    G = H // KV
    qk = q.reshape(B, Tq, KV, G, d).transpose(0, 2, 1, 3, 4)
    want = ref.paged_attention_ref(qk, kpg, vpg, bt, kv_lens, q_pos, scale=0.2)
    want = want.transpose(0, 2, 1, 3, 4).reshape(B, Tq, H, d)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("softcap,window", [(None, None), (20.0, None),
                                            (None, 7), (30.0, 5)])
def test_paged_attention_softcap_window(softcap, window):
    B, Tq, H, KV, d, ps, N, Pmax = 2, 4, 4, 2, 64, 8, 16, 4
    q, kpg, vpg, bt = _mk_paged(3, B, Tq, H, KV, d, ps, N, Pmax, jnp.float32)
    q_pos = jnp.asarray([8, 3], jnp.int32)
    kv_lens = q_pos + Tq
    out = ops.paged_attention(q, kpg, vpg, bt, kv_lens, q_pos, scale=0.2,
                              softcap=softcap, window=window)
    G = H // KV
    qk = q.reshape(B, Tq, KV, G, d).transpose(0, 2, 1, 3, 4)
    want = ref.paged_attention_ref(qk, kpg, vpg, bt, kv_lens, q_pos,
                                   scale=0.2, softcap=softcap, window=window)
    want = want.transpose(0, 2, 1, 3, 4).reshape(B, Tq, H, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


FLASH_CASES = [
    # B, T, Tk, H, KV, d, bq, bk
    (2, 32, 32, 4, 2, 64, 8, 8),
    (1, 64, 64, 8, 8, 128, 16, 16),
    (2, 16, 16, 6, 2, 32, 16, 8),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, T, Tk, H, KV, d, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(ks[0], (B, T, H, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, Tk, KV, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, Tk, KV, d)) * 0.5).astype(dtype)
    kv_lens = jnp.asarray([Tk] + [Tk - 5] * (B - 1), jnp.int32)
    out = ops.flash_attention(q, k, v, kv_lens, scale=0.2, block_q=bq,
                              block_k=bk)
    G = H // KV
    qk = q.reshape(B, T, KV, G, d).transpose(0, 2, 1, 3, 4)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    want = ref.flash_attention_ref(qk, kk, vv, kv_lens, scale=0.2)
    want = want.transpose(0, 2, 1, 3, 4).reshape(B, T, H, d)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_window_softcap():
    B, T, H, KV, d = 1, 32, 4, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, T, H, d)) * 0.5
    k = jax.random.normal(ks[1], (B, T, KV, d)) * 0.5
    v = jax.random.normal(ks[2], (B, T, KV, d)) * 0.5
    lens = jnp.asarray([T], jnp.int32)
    out = ops.flash_attention(q, k, v, lens, scale=0.2, window=8,
                              softcap=25.0, block_q=8, block_k=8)
    qk = q.reshape(B, T, KV, 1, d).transpose(0, 2, 1, 3, 4)
    want = ref.flash_attention_ref(qk, k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3), lens, scale=0.2,
                                   window=8, softcap=25.0)
    want = want.transpose(0, 2, 1, 3, 4).reshape(B, T, H, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_paged_kernel_is_splitwiser_unified():
    """One kernel serves both phases: decode (C=1) and chunked prefill
    (C=chunk) produce identical results to two separate ref calls on the
    same pool — the fused mixed-batch property."""
    B, H, KV, d, ps, N, Pmax = 2, 4, 2, 64, 8, 24, 6
    q1, kpg, vpg, bt = _mk_paged(11, B, 1, H, KV, d, ps, N, Pmax, jnp.float32)
    qc = jax.random.normal(jax.random.PRNGKey(12), (B, 8, H, d)) * 0.5
    lens_dec = jnp.asarray([30, 17], jnp.int32)
    out_dec = ops.paged_attention(q1, kpg, vpg, bt, lens_dec + 1, lens_dec,
                                  scale=0.2)
    start = jnp.asarray([4, 0], jnp.int32)
    out_chunk = ops.paged_attention(qc, kpg, vpg, bt, start + 8, start,
                                    scale=0.2)
    assert out_dec.shape == (B, 1, H, d)
    assert out_chunk.shape == (B, 8, H, d)
    assert bool(jnp.isfinite(out_dec).all() and jnp.isfinite(out_chunk).all())
