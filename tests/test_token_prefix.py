"""Token-level (partial-page) prefix reuse.

The contract under test: with ``prefix_cache_granularity="token"`` a
prompt that diverges *inside* a page still reuses every matched token —
the partially-matched page is COW-copied into the request's table and
prefill starts mid-page — while greedy streams stay bit-identical with
the cache off, and the full-page ("page") granularity keeps the PR-3
behaviour.  Budgeting: admission charges the transient page a partial
hit holds while its unreferenced donor is revived for the copy.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.kv_cache import PageAllocator
from repro.core.prefix_cache import PrefixCache

ARCH = "qwen3-0.6b"
MODES = ["sequential", "splitwiser", "splitwiser_mps"]
PS = 4
BASE = ServeConfig(max_batch=4, page_size=PS, n_pages=128,
                   max_pages_per_seq=16, prefill_chunk=PS, n_streams=2,
                   enable_prefix_cache=True)


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _midpage_requests(vocab, n=5, shared=PS - 1, tail=5, out=6, seed=1):
    """Prompts sharing ``shared`` (< page_size) tokens then diverging:
    full-page caching can never score a hit."""
    rng = np.random.RandomState(seed)
    sys_toks = list(rng.randint(2, vocab, size=shared))
    return [Request(rid=i,
                    prompt=sys_toks + list(rng.randint(2, vocab, size=tail)),
                    sampling=SamplingParams(max_new_tokens=out))
            for i in range(n)]


# ------------------------------------------------------------ trie units ---
def test_match_tokens_returns_best_partial_overlap():
    cache = PrefixCache(PS)
    alloc = PageAllocator(16, PS, cache=cache)
    p = alloc.alloc(1, 2)
    cache.insert(list(range(8)), p)
    # diverge inside the second page: full chain + partial donor
    pages, partial = cache.match_tokens([0, 1, 2, 3, 4, 5, 99, 100])
    assert pages == p[:1] and partial == (p[1], 2)
    # diverge inside the first page: no full pages, root-level partial
    pages, partial = cache.match_tokens([0, 1, 77])
    assert pages == [] and partial == (p[0], 2)
    # disjoint: nothing
    assert cache.match_tokens([9, 9, 9, 9]) == ([], None)
    # among siblings the longest overlap wins
    q = alloc.alloc(2, 1)
    cache.insert([0, 1, 2, 9], q)
    pages, partial = cache.match_tokens([0, 1, 2, 9, 9])
    assert pages == q      # exact full-page match beats any partial
    pages, partial = cache.match_tokens([0, 1, 2, 8])
    assert pages == [] and partial[1] == 3    # 3-token overlap beats 2


def test_partial_insert_registers_leaf_with_valid_length():
    cache = PrefixCache(PS)
    alloc = PageAllocator(16, PS, cache=cache)
    p = alloc.alloc(1, 2)
    # default contract unchanged: partial tails need explicit opt-in
    with pytest.raises(ValueError):
        cache.insert(list(range(6)), p)
    cache.insert(list(range(6)), p, allow_partial=True)
    node = cache._by_page[p[1]]
    assert node.n_valid == 2 and not node.children
    # the partial leaf serves only its valid span
    pages, partial = cache.match_tokens(list(range(8)))
    assert pages == p[:1] and partial == (p[1], 2)
    # a partial node never chains: a full insert creates a sibling
    q = alloc.alloc(2, 1)
    cache.insert(list(range(8)), p[:1] + q)
    assert cache.match(list(range(8))) == [p[0], q[0]]
    assert not cache._by_page[p[1]].children


def test_partial_leaf_cost_scales_with_valid_tokens():
    cache = PrefixCache(PS)
    alloc = PageAllocator(16, PS, cache=cache)
    full = alloc.alloc(1, 1)
    cache.insert(list(range(4)), full)
    part = alloc.alloc(2, 1)
    cache.insert([7, 8], part, allow_partial=True)
    assert cache.page_cost(part[0]) < cache.page_cost(full[0])


def test_cow_partial_allocator_accounting():
    cache = PrefixCache(PS)
    alloc = PageAllocator(16, PS, cache=cache)
    p = alloc.alloc(1, 1)
    cache.insert(list(range(4)), p)
    alloc.free(1)
    assert cache.n_reclaimable == 1
    # reclaimable donor: revived for the copy, parked again after
    src, dst = alloc.cow_partial(2, p[0])
    assert src == p[0] and alloc.owned(2) == [dst]
    assert alloc.ref_count(p[0]) == 0 and cache.n_reclaimable == 1
    assert not cache.is_cached(dst)
    assert alloc.n_partial_cow == 1 and alloc.n_cow == 1
    # referenced donor: refcount restored to its prior value
    alloc.share(3, [p[0]])
    src2, dst2 = alloc.cow_partial(4, p[0])
    assert src2 == p[0] and alloc.ref_count(p[0]) == 1
    assert alloc.owned(4) == [dst2]
    for rid in (2, 3, 4):
        alloc.free(rid)
    assert alloc.n_allocated == 0


# ------------------------------------------------- engine-level behavior ---
@pytest.mark.parametrize("mode", MODES)
def test_midpage_divergence_bit_identical_and_strictly_cheaper(setup, mode):
    """Token-level reuse must be a pure optimization: identical greedy
    streams vs cache-off AND vs page granularity, with strictly fewer
    prefill tokens computed than page granularity (which scores zero)."""
    model, params = setup
    outs, summ = {}, {}
    for arm, (gran, cache) in dict(
            off=("page", False), page=("page", True),
            token=("token", True)).items():
        serve = dataclasses.replace(BASE, mode=mode, enable_prefix_cache=cache,
                                    prefix_cache_granularity=gran)
        reqs = _midpage_requests(model.cfg.vocab_size)
        eng = Engine(model, params, serve)
        s = eng.run(reqs, max_steps=8000).summary()
        assert s["n_done"] == len(reqs)
        outs[arm], summ[arm] = [r.out_tokens for r in reqs], s
        assert eng.alloc.n_allocated == 0 and eng.idle()
    assert outs["token"] == outs["page"] == outs["off"]
    assert summ["page"]["cache_hit_rate"] == 0       # no full page is shared
    assert summ["token"]["cache_hit_rate"] > 0
    assert summ["token"]["n_partial_hits"] > 0
    assert (summ["token"]["prefill_tokens_computed"]
            < summ["page"]["prefill_tokens_computed"])
    # every partial hit materialized as a COW copy
    assert (summ["token"]["prefix_cache"]["n_partial_cow"]
            == summ["token"]["n_partial_hits"])


def test_cached_tokens_exact_for_identical_twin(setup):
    """A twin of a fully-cached prompt reuses everything but the final
    token (its logits must be recomputed): n_cached_tokens is exact at
    token granularity, not rounded down to full pages."""
    model, params = setup
    rng = np.random.RandomState(4)
    prompt = list(rng.randint(2, model.cfg.vocab_size, size=10))   # 2.5 pages
    serve = dataclasses.replace(BASE, mode="sequential")
    eng = Engine(model, params, serve)
    eng.run([Request(rid=0, prompt=list(prompt),
                     sampling=SamplingParams(max_new_tokens=2))],
            max_steps=500)
    twin = Request(rid=1, prompt=list(prompt),
                   sampling=SamplingParams(max_new_tokens=2))
    m = eng.run([twin], max_steps=500)
    assert m.req(1).n_cached_tokens == len(prompt) - 1
    assert m.n_partial_hits >= 1


def test_partial_tail_inserted_at_finish_only(setup):
    """Mid-flight inserts register full pages only (the tail is still
    being written); after finish the partial tail is cached too and a
    mid-page-divergent successor reuses it."""
    model, params = setup
    serve = dataclasses.replace(BASE, mode="sequential")
    eng = Engine(model, params, serve)
    rng = np.random.RandomState(5)
    prompt = list(rng.randint(2, model.cfg.vocab_size, size=6))    # 1.5 pages
    eng.run([Request(rid=0, prompt=list(prompt),
                     sampling=SamplingParams(max_new_tokens=4))],
            max_steps=500)
    cache = eng.prefix_cache
    # committed KV at finish = prompt + generated - 1 (the last token's
    # KV is never written) = 9 tokens: 2 full pages + a 1-token partial
    partial_nodes = [n for n in cache._nodes.values() if n.n_valid < PS]
    assert partial_nodes and all(not n.children for n in partial_nodes)
    # a successor diverging inside the tail page hits the partial leaf
    succ = Request(rid=1, prompt=prompt[:5] + [1, 1, 1],
                   sampling=SamplingParams(max_new_tokens=2))
    m = eng.run([succ], max_steps=500)
    assert m.req(1).n_cached_tokens == 5    # 1 full page + 1 partial token
    assert m.n_partial_hits >= 1


def test_page_granularity_preserves_pr3_behaviour(setup):
    """The "page" knob disables partial matching, COW copies, and
    partial-tail inserts entirely."""
    model, params = setup
    serve = dataclasses.replace(BASE, mode="splitwiser_mps",
                                prefix_cache_granularity="page")
    eng = Engine(model, params, serve)
    reqs = _midpage_requests(model.cfg.vocab_size)
    s = eng.run(reqs, max_steps=8000).summary()
    assert s["n_done"] == len(reqs)
    assert s["n_partial_hits"] == 0 and s["cached_tokens"] == 0
    assert all(n.n_valid == PS for n in eng.prefix_cache._nodes.values())


@pytest.mark.parametrize("mode", MODES)
def test_token_reuse_survives_page_pressure(setup, mode):
    """Preemption + reclaim + token-level reuse interleave on a small
    pool: every request completes with oracle-exact greedy streams."""
    model, params = setup
    reqs = _midpage_requests(model.cfg.vocab_size, n=5, tail=7, out=8)
    oracle = _midpage_requests(model.cfg.vocab_size, n=5, tail=7, out=8)
    Engine(model, params,
           dataclasses.replace(BASE, mode="sequential",
                               enable_prefix_cache=False)
           ).run(oracle, max_steps=8000)
    small = dataclasses.replace(BASE, mode=mode, n_pages=22,
                                max_pages_per_seq=12)
    eng = Engine(model, params, small)
    s = eng.run(reqs, max_steps=8000).summary()
    assert s["n_done"] == 5
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in oracle]
    assert eng.alloc.n_allocated == 0 and eng.idle()


# ------------------------------------------------------ admission budget ---
def test_admission_budget_charges_transient_partial_cow(setup):
    """cache_probe reports the transient page an unreferenced partial
    donor holds during the COW copy; admission_pages charges it on top
    of the miss pages (referenced donors are already capacity-held)."""
    model, params = setup
    serve = dataclasses.replace(BASE, mode="sequential")
    eng = Engine(model, params, serve)
    rng = np.random.RandomState(6)
    prompt = list(rng.randint(2, model.cfg.vocab_size, size=7))
    eng.run([Request(rid=0, prompt=list(prompt),
                     sampling=SamplingParams(max_new_tokens=1))],
            max_steps=500)
    # rid 0 finished: its pages (incl. partial tail) park reclaimable
    # tail sentinel 1 < 2 never collides with generated prompt tokens
    succ = Request(rid=1, prompt=prompt[:6] + [1, 1],
                   sampling=SamplingParams(max_new_tokens=1))
    n_hit, n_free_hit, cow_extra = eng.cache_probe(succ)
    assert n_hit == 1 and n_free_hit == 0      # reclaimable, not referenced
    assert cow_extra == 1                      # donor revive is transient
    base = eng.sched.admission_pages(succ, n_free_hit)
    assert eng.sched.admission_pages(succ, n_free_hit, cow_extra) == base + 1
    # with a live reader holding the chain, nothing transient to charge
    eng.alloc.share(99, eng.prefix_cache.match(prompt))
    donor = eng.prefix_cache.match_tokens(succ.prefill_tokens)[1][0]
    eng.alloc.share(99, [donor])
    assert eng.cache_probe(succ)[2] == 0


def test_granularity_knob_validated():
    with pytest.raises(ValueError, match="prefix_cache_granularity"):
        ServeConfig(prefix_cache_granularity="byte")
    with pytest.raises(ValueError, match="admission_age_weight"):
        ServeConfig(admission_age_weight=-1.0)
