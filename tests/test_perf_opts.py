"""Correctness of the §Perf optimizations: vocab-tiled fused CE and the
recompute-based flash backward must be EXACT (to fp tolerance) drop-ins."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import flash_attention, flash_attention_ckpt
from repro.train.losses import lm_loss_from_hidden, lm_loss_from_hidden_vtiled


@pytest.mark.parametrize("softcap", [None, 25.0])
def test_vtiled_ce_matches_chunked(softcap):
    B, T, D, Vp, vreal = 2, 12, 32, 512, 500
    hidden = jax.random.normal(jax.random.PRNGKey(0), (B, T, D)) * 0.5
    table = jax.random.normal(jax.random.PRNGKey(1), (Vp, D)) * 0.2
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, vreal)
    labels = labels.at[0, :3].set(-100)
    l1, n1 = lm_loss_from_hidden(hidden, labels, table, softcap=softcap,
                                 v_real=vreal)
    l2, n2 = lm_loss_from_hidden_vtiled(hidden, labels, table,
                                        softcap=softcap, v_real=vreal,
                                        vtile=128)
    assert abs(float(l1) - float(l2)) < 1e-4 and float(n1) == float(n2)
    g1 = jax.grad(lambda h, t: lm_loss_from_hidden(
        h, labels, t, softcap=softcap, v_real=vreal)[0], (0, 1))(hidden, table)
    g2 = jax.grad(lambda h, t: lm_loss_from_hidden_vtiled(
        h, labels, t, softcap=softcap, v_real=vreal, vtile=128)[0], (0, 1))(
        hidden, table)
    for a, b in zip(g1, g2, strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("window,cap", [(None, None), (7, None),
                                        (None, 20.0), (5, 30.0)])
def test_flash_ckpt_bwd_matches_autodiff(window, cap):
    B, T, H, KV, d = 2, 24, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, d)) * 0.5
    k = jax.random.normal(ks[1], (B, T, KV, d)) * 0.5
    v = jax.random.normal(ks[2], (B, T, KV, d)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def f_ref(q, k, v):
        return flash_attention(q, k, v, q_positions=pos, kv_positions=pos,
                               scale=0.25, window=window, attn_softcap=cap,
                               block_kv=8).sum()

    def f_new(q, k, v):
        return flash_attention_ckpt(q, k, v, pos, pos, None, scale=0.25,
                                    window=window, attn_softcap=cap,
                                    block_kv=8).sum()

    assert abs(float(f_ref(q, k, v)) - float(f_new(q, k, v))) < 1e-3
    g1 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_new, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2, strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_flash_int8_scales_path():
    """flash_attention with k_scale/v_scale == dequant-then-attend."""
    from repro.launch.spmd import q8_kv
    B, T, H, KV, d = 1, 16, 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, T, H, d)) * 0.5
    k = jax.random.normal(ks[1], (B, T, KV, d)) * 0.5
    v = jax.random.normal(ks[2], (B, T, KV, d)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    kq, kscale = q8_kv(k)
    vq, vscale = q8_kv(v)
    got = flash_attention(kq if False else q, kq, vq, q_positions=pos,
                          kv_positions=pos, scale=0.25, block_kv=8,
                          k_scale=kscale, v_scale=vscale)
    want = flash_attention(q, kq.astype(jnp.float32) * kscale,
                           vq.astype(jnp.float32) * vscale, q_positions=pos,
                           kv_positions=pos, scale=0.25, block_kv=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
