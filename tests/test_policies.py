"""Pluggable scheduling-policy layer (core/policies.py).

The contract under test: policies change *when* work happens, never
*what* is computed — greedy token streams are bit-identical across every
``admission x eviction x preempt`` combination in every engine mode —
while ``cache_aware`` admission co-schedules identical prompts (the
second one hits instead of double-missing) and ``cache_aware``
preemption prefers the victim whose resume is a remap.
"""
import dataclasses
import itertools

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.kv_cache import PageAllocator
from repro.core.metrics import EventRing
from repro.core.policies import (ADMISSION_POLICIES, EVICTION_POLICIES,
                                 PREEMPT_POLICIES, CacheAwarePreempt,
                                 LatestPreempt, make_eviction)
from repro.core.prefix_cache import PrefixCache

ARCH = "qwen3-0.6b"
MODES = ["sequential", "splitwiser", "splitwiser_mps"]
PS = 4
N_NEW = 8
BASE = ServeConfig(max_batch=3, page_size=PS, n_pages=26,
                   max_pages_per_seq=12, prefill_chunk=PS, n_streams=2,
                   enable_prefix_cache=True)
MATRIX = list(itertools.product(sorted(ADMISSION_POLICIES),
                                sorted(EVICTION_POLICIES),
                                ["latest", "cache_aware"]))


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _workload(vocab, seed=0):
    """Two tenant templates with adjacent twins plus a unique prompt —
    same-round identical prefixes AND diverging tails."""
    rng = np.random.RandomState(seed)
    a = list(rng.randint(2, vocab, size=12))
    b = list(rng.randint(2, vocab, size=12))
    prompts = [a + [11, 12], a + [13, 14], b + [15, 16], b + [17, 18],
               list(rng.randint(2, vocab, size=14))]
    return [Request(rid=i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=N_NEW))
            for i, p in enumerate(prompts)]


@pytest.fixture(scope="module")
def oracle(setup):
    """Cache-off, generous-pool greedy reference (modes are oracle-exact,
    so one suffices)."""
    model, params = setup
    serve = dataclasses.replace(BASE, mode="sequential", n_pages=128,
                                enable_prefix_cache=False)
    reqs = _workload(model.cfg.vocab_size)
    Engine(model, params, serve).run(reqs, max_steps=4000)
    return [r.out_tokens for r in reqs]


# ----------------------------------------------------------- full matrix ---
@pytest.mark.parametrize("mode", MODES)
def test_greedy_bit_identical_across_policy_matrix(setup, oracle, mode):
    """Every admission x eviction x preempt combination must complete the
    pressured shared-prefix workload with oracle-exact greedy streams."""
    model, params = setup
    for adm, ev, pre in MATRIX:
        serve = dataclasses.replace(BASE, mode=mode, admission_policy=adm,
                                    eviction_policy=ev, preempt_policy=pre)
        eng = Engine(model, params, serve)
        reqs = _workload(model.cfg.vocab_size)
        s = eng.run(reqs, max_steps=8000).summary()
        assert s["n_done"] == len(reqs), (adm, ev, pre)
        assert [r.out_tokens for r in reqs] == oracle, (adm, ev, pre)
        assert eng.alloc.n_allocated == 0 and eng.idle()


# ----------------------------------------------- cache-aware admission ----
@pytest.mark.parametrize("mode", MODES)
def test_cache_aware_admission_coschedules_identical_prompts(setup, mode):
    """Two identical prompts submitted together: under fcfs both miss
    (the twin's pages commit only after the shared admission round);
    under cache_aware the second is held one round and hits."""
    model, params = setup
    rng = np.random.RandomState(1)
    prompt = list(rng.randint(2, model.cfg.vocab_size, size=16))
    hits = {}
    for adm in ("fcfs", "cache_aware"):
        serve = dataclasses.replace(BASE, mode=mode, n_pages=128,
                                    admission_policy=adm)
        eng = Engine(model, params, serve)
        reqs = [Request(rid=i, prompt=list(prompt),
                        sampling=SamplingParams(max_new_tokens=4))
                for i in range(2)]
        s = eng.run(reqs, max_steps=2000).summary()
        hits[adm] = s
        assert s["n_done"] == 2
        assert reqs[0].out_tokens == reqs[1].out_tokens
    assert hits["fcfs"]["cache_hit_rate"] == 0          # double miss
    assert hits["cache_aware"]["cache_hit_rate"] > 0    # held, then remapped
    # at least the twin's full-page prefix; with token-level reuse up to
    # len-1 (sequential commits the whole prompt before the hold lifts;
    # chunked modes admit the twin as soon as the full pages are resident,
    # racing the donor's partial tail — see test_token_prefix for the
    # exact fully-resident case)
    assert ((len(prompt) - 1) // PS * PS
            <= hits["cache_aware"]["cached_tokens"] <= len(prompt) - 1)
    assert hits["cache_aware"]["policy_counters"]["admission_holds"] > 0


def test_cache_aware_admission_orders_resident_prefixes_first(setup):
    """A waiting queue mixing a cache-hit request behind misses: the hit
    is admitted first (reorder event), fcfs keeps arrival order."""
    model, params = setup
    rng = np.random.RandomState(2)
    vocab = model.cfg.vocab_size
    warm = list(rng.randint(2, vocab, size=12))
    cold = [list(rng.randint(2, vocab, size=12)) for _ in range(2)]
    serve = dataclasses.replace(BASE, mode="sequential", n_pages=128,
                                max_batch=1, admission_policy="cache_aware")
    eng = Engine(model, params, serve)
    # warm the cache with the template, run to completion
    eng.run([Request(rid=0, prompt=list(warm) + [21, 22],
                     sampling=SamplingParams(max_new_tokens=2))],
            max_steps=500)
    # two cold prompts ahead of a warm one; max_batch=1 admits one per round
    for i, p in enumerate([cold[0], cold[1], list(warm) + [23, 24]]):
        eng.submit(Request(rid=10 + i, prompt=list(p),
                           sampling=SamplingParams(max_new_tokens=2)))
    batch = eng.sched.take_prefillable()
    assert [r.rid for r in batch] == [12]           # the resident prefix won
    s = eng.metrics.summary()
    assert s["policy_counters"]["admission_reorders"] >= 1


def _starvation_rounds(eng, model, warm, cold_prompt, max_rounds=12):
    """Simulated admission rounds: a fresh hot-template request arrives
    every round (resident-prefix hit) while one cold request waits;
    returns the round the cold request was admitted, or None."""
    cold = Request(rid=500, prompt=list(cold_prompt), arrival=0.0,
                   sampling=SamplingParams(max_new_tokens=2))
    eng.submit(cold)
    for rnd in range(max_rounds):
        hot = Request(rid=600 + rnd, prompt=list(warm) + [40 + rnd],
                      arrival=float(rnd + 1),
                      sampling=SamplingParams(max_new_tokens=2))
        eng.submit(hot)
        batch = eng.sched.take_prefillable()
        assert len(batch) <= 1
        if any(r.rid == 500 for r in batch):
            return rnd
    return None


@pytest.mark.parametrize("age_weight,starves", [(0.0, True), (0.5, False)])
def test_cache_aware_admission_aging_bounds_cold_prefix_wait(
        setup, age_weight, starves):
    """Under a sustained hot-template stream with one admission slot per
    round, pure hit-first ordering (age_weight=0) starves the cold
    request indefinitely; the default age-weighted score admits it once
    accumulated wait rounds outweigh the hot requests' resident pages."""
    model, params = setup
    rng = np.random.RandomState(9)
    vocab = model.cfg.vocab_size
    warm = list(rng.randint(2, vocab, size=12))
    serve = dataclasses.replace(BASE, mode="sequential", n_pages=128,
                                max_batch=1, admission_policy="cache_aware",
                                admission_age_weight=age_weight)
    eng = Engine(model, params, serve)
    # make the template resident (run a warm request to completion)
    eng.run([Request(rid=0, prompt=list(warm) + [30],
                     sampling=SamplingParams(max_new_tokens=2))],
            max_steps=500)
    admitted = _starvation_rounds(eng, model, warm,
                                  list(rng.randint(2, vocab, size=12)))
    if starves:
        assert admitted is None     # ROADMAP "admission aging" bug, pinned
    else:
        # resident hit = 2 pages; 0.5/round => outranked within ~5 rounds
        assert admitted is not None and admitted <= 6


# ----------------------------------------------- cache-aware preemption ---
def test_cache_aware_preempt_picks_remappable_victim(setup):
    """Two eligible victims: an older one whose committed KV is shared
    with a live reader (resume = remap) and the latest arrival with
    private pages (resume = full recompute).  ``latest`` takes the
    newest; ``cache_aware`` takes the remappable one."""
    model, params = setup
    serve = dataclasses.replace(BASE, mode="sequential", n_pages=64)
    eng = Engine(model, params, serve)
    cache, alloc = eng.prefix_cache, eng.alloc

    shared = Request(rid=2, prompt=list(range(2, 10)), arrival=2.0,
                     sampling=SamplingParams(max_new_tokens=4))
    private = Request(rid=3, prompt=list(range(30, 38)), arrival=3.0,
                      sampling=SamplingParams(max_new_tokens=4))
    pages_s = alloc.alloc(shared.rid, 2)
    cache.insert(shared.prompt, pages_s)
    alloc.share(99, pages_s)                 # live co-reader keeps them warm
    pages_p = alloc.alloc(private.rid, 2)
    cache.insert(private.prompt, pages_p)    # cached but refcount 1: parks
                                             # reclaimable on eviction
    cands = [("slot", 0, shared, 8), ("slot", 1, private, 8)]
    assert LatestPreempt().select(list(cands), eng) == ("slot", 1)
    assert CacheAwarePreempt().select(list(cands), eng) == ("slot", 0)
    assert eng.metrics.policy_counters["cheap_preemptions"] == 1
    assert eng.resume_safe_pages(shared, 8) == 2
    assert eng.resume_safe_pages(private, 8) == 0


def test_cache_aware_preempt_degenerates_to_latest_when_cold(setup):
    """With no surviving cached pages every score ties at zero and the
    latest arrival is picked — same victim as ``latest``."""
    model, params = setup
    serve = dataclasses.replace(BASE, mode="sequential", n_pages=64)
    eng = Engine(model, params, serve)
    reqs = [Request(rid=i, prompt=list(range(10 * i, 10 * i + 8)),
                    arrival=float(i), sampling=SamplingParams(max_new_tokens=4))
            for i in range(3)]
    for r in reqs:
        eng.alloc.alloc(r.rid, 2)
    cands = [("slot", i, r, 8) for i, r in enumerate(reqs)]
    assert (CacheAwarePreempt().select(list(cands), eng)
            == LatestPreempt().select(list(cands), eng) == ("slot", 2))


# ------------------------------------------------------- cost eviction ----
def test_cost_eviction_strips_cheapest_leaf_first():
    """Two reclaimable leaves: a shallow one (cheap recompute) and the
    deep end of a chain (expensive — attention replays its whole
    prefix).  LRU would evict the deep leaf (least recently touched);
    the cost model strips the shallow one."""
    cache = PrefixCache(4, policy="cost")
    alloc = PageAllocator(16, 4, cache=cache)
    chain = alloc.alloc(1, 3)
    cache.insert(list(range(12)), chain)            # depth 0..2
    lone = alloc.alloc(2, 1)
    cache.insert(list(range(100, 104)), lone)       # depth 0
    alloc.free(1)
    alloc.free(2)
    cache.touch(chain)       # deep leaf now LRU-oldest? no: bump chain,
    cache.touch(lone)        # then lone — LRU would evict the chain leaf
    assert make_eviction("lru").rank(cache._by_page[chain[2]], cache) \
        < make_eviction("lru").rank(cache._by_page[lone[0]], cache)
    # cost: the depth-2 chain page is ~3x the recompute of the lone leaf
    assert cache.page_cost(chain[2]) > cache.page_cost(lone[0])
    assert cache.pop_reclaimable() == lone[0]
    # remaining reclaimable leaves strip deepest-last
    assert cache.pop_reclaimable() == chain[2]


def test_page_cost_counts_descendants():
    """A page anchoring a cached subtree is worth more than its own
    recompute: descendants weight the cost."""
    cache = PrefixCache(2)
    cache.insert([1, 2, 3, 4, 5, 6], [10, 11, 12])
    cache.insert([1, 2, 3, 4, 7, 8], [10, 11, 13])   # sibling leaf
    root_cost = cache.page_cost(10)
    assert cache._by_page[10].n_desc == 3
    assert root_cost > cache.page_cost(12)           # subtree beats depth
    cache._evict(cache._by_page[13])
    assert cache._by_page[10].n_desc == 2
    assert cache.page_cost(10) < root_cost


def test_blocked_reclaimable_page_still_strippable():
    """An interior-write COW can release a mid-chain cached page while
    its deeper pages stay mapped: the reclaimable page then has
    *referenced* descendants, so no leaf-first strip can reach it — yet
    ``n_free`` counts it.  The allocator must keep the capacity promise
    (evicting the blocking subtree from the trie) instead of raising
    OutOfPages with a page nominally free."""
    cache = PrefixCache(4, policy="lru")
    alloc = PageAllocator(6, 4, cache=cache)       # 5 usable pages
    chain = alloc.alloc(1, 2)
    cache.insert(list(range(8)), chain)
    # interior write: page 0 is COW'd, parks reclaimable above the still-
    # referenced page 1
    (src, dst), = alloc.prepare_write(1, 0)
    assert src == chain[0] and cache.n_reclaimable == 1
    assert cache._by_page[src].n_children == 1     # blocked: not a leaf
    # free list now: 5 usable - 3 held (dst, chain[1], src-reclaimable) = 2
    alloc.alloc(2, 2)
    assert alloc.n_free == 1                       # only the blocked page
    pages = alloc.alloc(3, 1)                      # must not raise
    assert pages == [src]
    assert not cache.is_cached(chain[1])           # subtree left the trie
    assert alloc.owned(1) == [dst, chain[1]]       # ...but stays owned
    alloc.free(1)
    assert alloc.n_free == 2                       # uncached pages free up


def test_blocked_reclaimable_evicts_whole_subtree_via_child_links():
    """The interior-COW blocking case with a deep chain: the reclaimable
    mid-chain page sits above a 2-node *referenced* subtree.  The strip
    must walk the explicit child links, evict the whole subtree from the
    trie (pages stay owned), and hand back the blocked page."""
    cache = PrefixCache(4, policy="lru")
    alloc = PageAllocator(8, 4, cache=cache)       # 7 usable pages
    chain = alloc.alloc(1, 3)
    cache.insert(list(range(12)), chain)
    node0 = cache._by_page[chain[0]]
    assert [c.page for c in node0.children.values()] == [chain[1]]
    # interior write: page 0 COWs, parks reclaimable above 2 referenced
    # descendants — no leaf-first strip can reach it
    (src, dst), = alloc.prepare_write(1, 0)
    assert src == chain[0] and cache.n_reclaimable == 1
    assert cache._by_page[src].n_children == 1 and \
        cache._by_page[src].n_desc == 2
    alloc.alloc(2, 3)                              # free list now empty
    pages = alloc.alloc(3, 1)                      # strips the blocked page
    assert pages == [src]
    assert not cache.is_cached(chain[1]) and not cache.is_cached(chain[2])
    assert alloc.owned(1) == [dst, chain[1], chain[2]]   # still owned
    assert cache.n_cached_pages == 0 and cache.n_reclaimable == 0
    alloc.free(1)
    assert alloc.n_free == 3


# ------------------------------------------------------- config wiring ----
def test_policy_knobs_validated():
    with pytest.raises(ValueError, match="admission_policy"):
        ServeConfig(admission_policy="lifo")
    with pytest.raises(ValueError, match="eviction_policy"):
        ServeConfig(eviction_policy="mru")
    with pytest.raises(ValueError, match="preempt_policy"):
        ServeConfig(preempt_policy="oldest")
    with pytest.raises(ValueError, match="sched_events_cap"):
        ServeConfig(sched_events_cap=0)
    assert set(PREEMPT_POLICIES) == {"latest", "cache_aware", "deadline"}


def test_eviction_policy_inherits_legacy_knob(setup):
    model, params = setup
    eng = Engine(model, params,
                 dataclasses.replace(BASE, prefix_cache_policy="fifo"))
    assert eng.prefix_cache.policy == "fifo"
    eng = Engine(model, params,
                 dataclasses.replace(BASE, prefix_cache_policy="fifo",
                                     eviction_policy="cost"))
    assert eng.prefix_cache.policy == "cost"


# --------------------------------------------------- sched_events ring ----
def test_sched_events_ring_caps_and_counts_drops():
    ring = EventRing(cap=3)
    for i in range(5):
        ring.append({"i": i})
    assert len(ring) == 3
    assert ring.n_dropped == 2
    assert ring.n_total == 5
    assert [e["i"] for e in ring] == [2, 3, 4]
    assert ring[0]["i"] == 2 and ring[-1]["i"] == 4
    assert [e["i"] for e in ring[1:]] == [3, 4]
    assert bool(ring)
    with pytest.raises(ValueError, match="cap"):
        EventRing(cap=0)


def test_engine_sched_events_capped_via_config(setup):
    """A long pressured run with a tiny cap keeps the trace bounded and
    counts the overflow in summary()."""
    model, params = setup
    serve = dataclasses.replace(BASE, mode="sequential", sched_events_cap=4)
    eng = Engine(model, params, serve)
    reqs = _workload(model.cfg.vocab_size)
    m = eng.run(reqs, max_steps=8000)
    assert m.summary()["n_done"] == len(reqs)
    assert len(m.sched_events) <= 4
    assert m.sched_events.n_dropped > 0
    assert m.summary()["sched_events_dropped"] == m.sched_events.n_dropped
