"""Scheduler subsystem: watermark admission + preemption by recomputation.

The paper's constrained-resource premise (Fig. 5/14/15: KV usage climbs
toward exhaustion) must be a served scenario, not a crash: an
oversubscribed page pool has to complete every request in every engine
mode, and a preempted-and-resumed request must produce exactly the
greedy tokens of an unpreempted run.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.kv_cache import OutOfPages

ARCH = "qwen3-0.6b"
N_NEW = 16
MODES = ["sequential", "splitwiser", "splitwiser_mps"]

# pool of 19 usable pages (page_size 4) vs 4 requests that each grow to
# ceil((12+16)/4) = 7 pages -> the pool holds barely 2 full sequences
SMALL = ServeConfig(max_batch=4, page_size=4, n_pages=20,
                    max_pages_per_seq=12, prefill_chunk=4, n_streams=2)
BIG = dataclasses.replace(SMALL, n_pages=128)


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(2, model.cfg.vocab_size, size=n))
               for n in (12, 11, 12, 10)]
    # unpreempted baseline (generous pool); all modes are oracle-exact,
    # so one mode suffices as the reference
    eng = Engine(model, params, dataclasses.replace(BIG, mode="sequential"))
    base = [Request(rid=i, prompt=list(p), sampling=SamplingParams(max_new_tokens=N_NEW))
            for i, p in enumerate(prompts)]
    m = eng.run(base, max_steps=4000)
    assert m.summary()["n_preemptions"] == 0
    return model, params, prompts, [r.out_tokens for r in base]


def _requests(prompts):
    return [Request(rid=i, prompt=list(p), sampling=SamplingParams(max_new_tokens=N_NEW))
            for i, p in enumerate(prompts)]


@pytest.mark.parametrize("mode", MODES)
def test_oversubscribed_pool_completes_every_request(setup, mode):
    """Regression for the seed OutOfPages crash: tiny pool, generations
    that outgrow the pages reserved at admission."""
    model, params, prompts, _ = setup
    eng = Engine(model, params, dataclasses.replace(SMALL, mode=mode))
    reqs = _requests(prompts)
    m = eng.run(reqs, max_steps=4000)
    s = m.summary()
    assert s["n_done"] == len(reqs)
    assert all(len(r.out_tokens) == N_NEW for r in reqs)
    assert s["n_preemptions"] > 0          # the pool really was oversubscribed
    assert s["n_preemptions"] == len(
        [e for e in m.sched_events if e["event"] == "preempt"])
    assert eng.alloc.n_allocated == 0 and eng.idle()


@pytest.mark.parametrize("mode", MODES)
def test_preempted_resume_matches_unpreempted_greedy(setup, mode):
    model, params, prompts, oracle = setup
    eng = Engine(model, params, dataclasses.replace(SMALL, mode=mode))
    reqs = _requests(prompts)
    m = eng.run(reqs, max_steps=4000)
    assert m.summary()["n_preemptions"] > 0
    assert [r.out_tokens for r in reqs] == oracle


def test_seed_policy_none_still_crashes(setup):
    """preempt_policy="none" reproduces the seed failure mode (kept for
    graceful-degradation comparisons in benchmarks)."""
    model, params, prompts, _ = setup
    serve = dataclasses.replace(SMALL, mode="sequential",
                                preempt_policy="none",
                                watermark=0.0, decode_reserve=0.0)
    eng = Engine(model, params, serve)
    with pytest.raises(OutOfPages):
        eng.run(_requests(prompts), max_steps=4000)


def test_submit_rejects_duplicate_rid(setup):
    model, params, prompts, _ = setup
    eng = Engine(model, params, dataclasses.replace(BIG, mode="sequential"))
    eng.submit(Request(rid=7, prompt=list(prompts[0]), sampling=SamplingParams(max_new_tokens=2)))
    with pytest.raises(ValueError, match="duplicate request id"):
        eng.submit(Request(rid=7, prompt=list(prompts[1]), sampling=SamplingParams(max_new_tokens=2)))


def test_timesliced_skips_empty_prefill_dispatch(setup):
    """When slot backpressure filters out every chunk, the timesliced
    step must not dispatch an all-zero mixed program (seed recorded a
    bogus "prefill_chunk" step)."""
    model, params, prompts, _ = setup
    serve = dataclasses.replace(BIG, mode="splitwiser", max_batch=1)
    eng = Engine(model, params, serve)
    dispatches = []
    orig = eng._mixed

    def spy(p, mb, kpg, vpg):
        dispatches.append((int(np.asarray(mb["p_lens"]).sum()),
                           int(np.asarray(mb["d_active"]).size)))
        return orig(p, mb, kpg, vpg)

    eng._mixed = spy
    reqs = [Request(rid=0, prompt=list(prompts[0][:4]), sampling=SamplingParams(max_new_tokens=12)),
            Request(rid=1, prompt=list(prompts[1][:4]), sampling=SamplingParams(max_new_tokens=4))]
    m = eng.run(reqs, max_steps=2000)
    assert m.summary()["n_done"] == 2
    assert all(p_sum > 0 or d_size > 0 for p_sum, d_size in dispatches), \
        "dispatched an empty mixed program"


# ----------------------------------------------------- admission units ----
def _engine(model, params, **kw):
    return Engine(model, params, ServeConfig(mode="sequential", **kw))


def test_admission_honours_watermark(setup):
    model, params, prompts, _ = setup
    # 16 usable pages, watermark keeps 4 free; each request budgets
    # ceil((8 + 1 + 4)/4) = 4 pages -> exactly 3 admitted
    eng = _engine(model, params, max_batch=8, page_size=4, n_pages=17,
                  max_pages_per_seq=8, watermark=0.25, decode_reserve=0.5)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=list(prompts[0][:8]),
                           sampling=SamplingParams(max_new_tokens=9)))
    batch = eng.sched.take_prefillable()
    assert len(batch) == 3
    assert len(eng.waiting) == 2


def test_admission_head_of_line_progress_override(setup):
    """A request whose watermarked budget never fits must still run when
    the pool is idle and its bare prompt fits."""
    model, params, prompts, _ = setup
    eng = _engine(model, params, max_batch=4, page_size=4, n_pages=17,
                  max_pages_per_seq=12, watermark=0.25, decode_reserve=1.0)
    # bare: ceil(41/4) = 11 <= 16 free, but budgeted need is far larger
    big = Request(rid=0, prompt=list(np.tile(prompts[0], 4)[:40]),
                  sampling=SamplingParams(max_new_tokens=64))
    eng.submit(big)
    batch = eng.sched.take_prefillable()
    assert [r.rid for r in batch] == [0]


def test_unservable_request_raises_clear_error(setup):
    model, params, prompts, _ = setup
    eng = _engine(model, params, max_batch=4, page_size=4, n_pages=9,
                  max_pages_per_seq=32)
    eng.submit(Request(rid=0, prompt=list(np.tile(prompts[0], 10)[:100]),
                       sampling=SamplingParams(max_new_tokens=4)))
    with pytest.raises(OutOfPages, match="pool only has"):
        eng.sched.take_prefillable()


def test_block_table_overflow_raises_clear_error(setup):
    """A sequence that fits the pool but outgrows max_pages_per_seq must
    fail with a sizing message, not a numpy broadcast crash."""
    model, params, prompts, _ = setup
    # prompt alone exceeds the block-table row: rejected at admission
    eng = _engine(model, params, max_batch=4, page_size=4, n_pages=20,
                  max_pages_per_seq=3)
    eng.submit(Request(rid=0, prompt=list(np.tile(prompts[0], 4)[:40]),
                       sampling=SamplingParams(max_new_tokens=4)))
    with pytest.raises(OutOfPages, match="max_pages_per_seq"):
        eng.sched.take_prefillable()
    # generation outgrows the row mid-decode: rejected at extension
    eng = _engine(model, params, max_batch=4, page_size=4, n_pages=64,
                  max_pages_per_seq=3)
    with pytest.raises(OutOfPages, match="max_pages_per_seq"):
        eng.run([Request(rid=0, prompt=list(prompts[0][:8]),
                         sampling=SamplingParams(max_new_tokens=30))], max_steps=200)


def test_invalid_preempt_policy_rejected(setup):
    model, params, _, _ = setup
    with pytest.raises(ValueError, match="preempt_policy"):
        _engine(model, params, preempt_policy="latets")
