"""SSD chunk-scan Pallas kernel vs the ssm.mamba2_chunk_scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd_chunk_scan
from repro.models.ssm import mamba2_chunk_scan


@pytest.mark.parametrize("case", [
    # B, H, T, P, N, chunk
    (2, 3, 32, 16, 8, 8),
    (1, 2, 64, 32, 16, 16),
    (2, 1, 24, 8, 8, 8),
])
def test_ssd_kernel_vs_oracle(case):
    B, H, T, P, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xdt = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
    Bc = jax.random.normal(ks[1], (B, T, N)) * 0.5
    Cc = jax.random.normal(ks[2], (B, T, N)) * 0.5
    la = -jnp.abs(jax.random.normal(ks[3], (B, T, H))) * 0.1

    h0 = jnp.zeros((B, H, P, N))
    want_y, want_h = mamba2_chunk_scan(xdt, Bc, Cc, la, h0, chunk=chunk)

    y, h = ssd_chunk_scan(xdt.transpose(0, 2, 1, 3),
                          la.transpose(0, 2, 1), Bc, Cc, chunk=chunk,
                          interpret=True)
    y = y.transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want_y), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want_h), atol=1e-4)
