"""Jit-dispatch sentinel (analysis/dispatch.py): compile counting proven
against real ``jax.jit`` cache behaviour, the storm guard proven by an
injected recompile storm, and the engine wiring proven compiled-once —
a full warmed-up workload re-run triggers zero post-warmup recompiles.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.analysis.dispatch import (STORM_THRESHOLD, STORM_WINDOW,
                                     DispatchSentinel)
from repro.analysis.invariants import InvariantViolation
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams


# ------------------------------------------------------- unit: counting ----
def test_counts_real_jit_compiles():
    sent = DispatchSentinel()
    fn = sent.wrap("f", jax.jit(lambda x: x * 2))
    fn(jnp.ones((4,)))
    fn(jnp.ones((4,)))                    # cache hit
    fn(jnp.ones((4,)))
    assert sent.stats["f"].n_compiles == 1
    fn(jnp.ones((8,)))                    # new shape -> new compile
    assert sent.stats["f"].n_compiles == 2
    assert sent.stats["f"].n_calls == 4


def test_warm_budget_with_real_jit():
    sent = DispatchSentinel()
    fn = sent.wrap("f", jax.jit(lambda x: x + 1))
    fn(jnp.ones((4,)))
    fn(jnp.ones((8,)))
    sent.mark_warm()
    fn(jnp.ones((4,)))                    # warm dispatch, no compile
    sent.check(budget=0)                  # compiled-once holds
    fn(jnp.ones((16,)))                   # post-warmup recompile
    assert sent.post_warm_compiles() == {"f": 1}
    with pytest.raises(InvariantViolation) as e:
        sent.check(budget=0)
    assert e.value.invariant == "jit_dispatch"
    assert "dispatch" in e.value.state
    sent.check(budget=1)                  # explicit budget absorbs it


def test_fallback_signature_probe_for_plain_callables():
    # no _cache_size -> duck-typed signatures stand in for the jit cache
    sent = DispatchSentinel()
    fn = sent.wrap("plain", lambda x, flag=False: x)
    fn(np.ones((4,)))
    fn(np.ones((4,)))                     # same signature: no compile
    fn(np.ones((4,), dtype=np.int32))     # dtype change counts
    fn(np.ones((4,)), flag=True)          # kwarg *value* change counts
    assert sent.stats["plain"].n_compiles == 3
    assert sent.stats["plain"].n_calls == 4


# --------------------------------------------------- unit: storm guard ----
def _storm(fn, n):
    for i in range(n):
        fn(jnp.ones((i + 1,)))            # every call a fresh shape


def test_storm_guard_catches_injected_recompile_storm():
    sent = DispatchSentinel()
    fn = sent.wrap("decode", jax.jit(lambda x: x.sum()), storm_guard=True)
    with pytest.raises(InvariantViolation) as e:
        _storm(fn, STORM_WINDOW + 1)
    assert e.value.invariant == "jit_dispatch"
    assert "recompile storm" in str(e.value)
    # the guard waited for a full window before judging density
    assert sent.stats["decode"].n_calls >= STORM_WINDOW
    assert sent.stats["decode"].n_compiles >= STORM_THRESHOLD


def test_storm_guard_off_only_counts():
    # prefill/commit legitimately see per-workload shape diversity
    sent = DispatchSentinel()
    fn = sent.wrap("prefill", jax.jit(lambda x: x.sum()), storm_guard=False)
    _storm(fn, STORM_WINDOW + 8)          # same storm, no raise
    assert sent.stats["prefill"].n_compiles == STORM_WINDOW + 8


def test_sparse_recompiles_below_threshold_pass():
    sent = DispatchSentinel(storm_window=8, storm_threshold=4)
    fn = sent.wrap("decode", jax.jit(lambda x: x * 1.0), storm_guard=True)
    shapes = [4, 8]                       # two shapes, then all cache hits
    for i in range(32):
        fn(jnp.ones((shapes[i % 2],)))    # density 2/8 < 4: healthy


# -------------------------------------------------------- engine wiring ----
ARCH = "qwen3-0.6b"

SMALL = ServeConfig(max_batch=4, page_size=4, n_pages=20,
                    max_pages_per_seq=12, prefill_chunk=4, n_streams=2,
                    enable_prefix_cache=True, sanitize_level="off",
                    dispatch_sentinel=True)


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    shared = list(rng.randint(2, model.cfg.vocab_size, size=8))
    prompts = [shared + list(rng.randint(2, model.cfg.vocab_size, size=4))
               for _ in range(4)]
    return model, params, prompts


def _requests(prompts, base_rid=0, n_new=8):
    return [Request(rid=base_rid + i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=n_new))
            for i, p in enumerate(prompts)]


@pytest.mark.parametrize("mode", ["sequential", "splitwiser", "splitwiser_mps"])
def test_engine_hot_path_is_compiled_once(setup, mode):
    """Warmed-up engine, then an identical workload: zero post-warmup
    recompiles on every step callable — the acceptance criterion for the
    sentinel wiring.  Warmup is two runs, not one: the second run hits
    the prefix cache the first populated, which legitimately changes
    batch composition (shorter prefills), so steady-state shapes only
    stabilise from the second run on."""
    model, params, prompts = setup
    eng = Engine(model, params, dataclasses.replace(SMALL, mode=mode))
    eng.run(_requests(prompts), max_steps=4000)
    eng.run(_requests(prompts, base_rid=50), max_steps=4000)
    assert eng.dispatch is not None
    assert eng.dispatch.total_compiles > 0          # probe saw the warmup
    eng.dispatch.mark_warm()
    eng.run(_requests(prompts, base_rid=100), max_steps=4000)
    eng.dispatch.check(budget=0)                    # raises on any recompile
    assert all(n == 0 for n in eng.dispatch.post_warm_compiles().values())


def test_engine_report_names_step_callables(setup):
    model, params, prompts = setup
    eng = Engine(model, params, dataclasses.replace(SMALL, mode="splitwiser"))
    eng.run(_requests(prompts), max_steps=4000)
    report = eng.dispatch.report()
    assert "mixed" in report or "decode" in report
    for row in report.values():
        assert set(row) == {"calls", "compiles", "post_warm"}


def test_sentinel_off_by_default(setup):
    model, params, prompts = setup
    eng = Engine(model, params,
                 dataclasses.replace(SMALL, dispatch_sentinel=False))
    assert eng.dispatch is None
    eng.run(_requests(prompts, n_new=4), max_steps=4000)   # still runs clean


def test_env_default(monkeypatch):
    monkeypatch.delenv("REPRO_DISPATCH_SENTINEL", raising=False)
    assert ServeConfig().dispatch_sentinel is False
    monkeypatch.setenv("REPRO_DISPATCH_SENTINEL", "1")
    assert ServeConfig().dispatch_sentinel is True
    monkeypatch.setenv("REPRO_DISPATCH_SENTINEL", "0")
    assert ServeConfig().dispatch_sentinel is False
