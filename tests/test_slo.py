"""SLO-aware deadline scheduling: unit + end-to-end proofs.

Covers the deadline admission/preemption policies and the SLO plumbing
around them:

* slack-ranked (EDF) admission ordering, with exact FCFS degeneration —
  and **zero clock reads** — when no waiting request carries a deadline;
* per-tenant token quotas: the hold predicate's single-oversized-request
  progress exemption, and the end-to-end regression that a quota bounds
  a burst tenant's head-of-line damage (the gold request's TTFT drops
  when the quota engages, same workload otherwise);
* max-slack preemption victims vs the ``latest`` oracle, including the
  all-infinite-slack degeneration;
* the headline invariant: deadline policies change *when* work happens,
  never *what* — greedy streams stay oracle-exact across all four modes
  when no deadline binds, under the step-level sanitizer;
* a hypothesis interleaving arm randomizing tenant mixes and quotas;
* mutation-style proof that the sanitizer's ``tenant_quota`` check is
  live (disable the hold → the checker must fail the run).
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.analysis.invariants import InvariantViolation
from repro.configs import ServeConfig
from repro.configs.base import TenantTier
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.policies import (DeadlineAdmission, DeadlinePreempt,
                                 LatestPreempt)
from repro.core.slo import (SLOParams, request_footprint, resolve_slo,
                            slo_outcome)

ARCH = "qwen3-0.6b"
PS = 4
MODES = ("sequential", "splitwiser", "splitwiser_mps", "chunked")

TIERS = (TenantTier("gold", ttft_target=0.05, tbt_target=0.5, weight=4.0),
         TenantTier("batch", quota_tokens=40))
BASE = ServeConfig(max_batch=3, page_size=PS, n_pages=26,
                   max_pages_per_seq=12, prefill_chunk=PS, n_streams=2,
                   enable_prefix_cache=True, admission_policy="deadline",
                   preempt_policy="deadline", tenants=TIERS)


class _CountingClock:
    def __init__(self, tick: float = 1e-4):
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _req(rid, n=8, *, tenant="default", arrival=None, n_new=4, base=100):
    return Request(rid=rid, prompt=list(range(base, base + n)),
                   arrival=arrival, sampling=SamplingParams(max_new_tokens=n_new),
                   slo=SLOParams(tenant=tenant))


# ------------------------------------------------------- params + tiers ---
def test_slo_params_validation():
    assert SLOParams().has_target is False
    assert SLOParams(ttft_target=0.5).has_target
    for bad in (dict(ttft_target=0.0), dict(ttft_target=-1),
                dict(tbt_target="fast"), dict(tbt_target=True),
                dict(tenant=""), dict(tenant=7)):
        with pytest.raises((TypeError, ValueError)):
            SLOParams(**bad)


def test_tenant_tier_validation():
    for bad in (dict(name=""), dict(name="a", ttft_target=0),
                dict(name="a", quota_tokens=0),
                dict(name="a", quota_tokens=1.5),
                dict(name="a", weight=0), dict(name="a", weight=-2)):
        with pytest.raises((TypeError, ValueError)):
            TenantTier(**bad)
    with pytest.raises(ValueError):       # duplicate tenant names
        dataclasses.replace(BASE, tenants=(TenantTier("a"), TenantTier("a")))
    with pytest.raises(ValueError):
        dataclasses.replace(BASE, slo_page_cost=-0.1)


def test_resolve_slo_request_overrides_tier():
    tiers = {t.name: t for t in TIERS}
    eff = resolve_slo(SLOParams(tenant="gold"), tiers)
    assert (eff.ttft_target, eff.tbt_target, eff.weight) == (0.05, 0.5, 4.0)
    # explicit request target wins over the tier's; quota/weight are
    # tier-only knobs
    eff = resolve_slo(SLOParams(tenant="gold", ttft_target=0.01), tiers)
    assert eff.ttft_target == 0.01 and eff.weight == 4.0
    # unknown-tenant and default-tenant requests resolve deadline-free
    assert not resolve_slo(SLOParams(tenant="other"), tiers).has_deadline
    assert resolve_slo(SLOParams(), {}).quota_tokens is None


def test_slo_outcome_semantics():
    eff = resolve_slo(SLOParams(tenant="gold"), {t.name: t for t in TIERS})
    assert slo_outcome(0.01, 0.1, eff) is True
    assert slo_outcome(0.06, 0.1, eff) is False      # TTFT blown
    assert slo_outcome(0.01, 0.6, eff) is False      # worst gap blown
    assert slo_outcome(None, None, eff) is False     # never started
    no = resolve_slo(SLOParams(), {})
    assert slo_outcome(0.01, 0.1, no) is None        # nothing to judge


# ------------------------------------------------- admission ordering ----
def test_deadline_admission_orders_by_slack(setup):
    model, params = setup
    clock = _CountingClock()
    eng = Engine(model, params, BASE, time_fn=clock)
    late = _req(0, tenant="gold", arrival=0.30, base=10)
    early = _req(1, tenant="gold", arrival=0.01, base=30)
    free = _req(2, arrival=0.0, base=50)             # no deadline: back
    for r in (free, late, early):
        eng.submit(r)
    out = DeadlineAdmission().order(eng.sched)
    # EDF: earlier deadline (arrival + target) first; deadline-free last
    assert [r.rid for r in out] == [1, 0, 2]
    assert eng.metrics.policy_counters["admission_reorders"] == 1


def test_slo_page_cost_charges_expensive_prefills(setup):
    """With ``slo_page_cost`` set, slack is debited per page the
    admission would allocate (the probe/``admission_pages`` predictor):
    of two equal-deadline requests the page-hungry one has *less* true
    slack — servicing it takes longer — so it is admitted earlier."""
    model, params = setup
    def order_with(serve):
        eng = Engine(model, params, serve, time_fn=_CountingClock())
        small = _req(0, n=4, tenant="gold", arrival=0.0, base=10)
        big = _req(1, n=40, tenant="gold", arrival=0.0, base=100)
        for r in (small, big):            # fcfs order: small first
            eng.submit(r)
        return [r.rid for r in DeadlineAdmission().order(eng.sched)]

    # page cost promotes the page-hungry request past an equal deadline
    assert order_with(dataclasses.replace(BASE, slo_page_cost=0.01)) == [1, 0]
    # cost off: equal slack, (arrival, rid) tie-break keeps fcfs order
    assert order_with(BASE) == [0, 1]


def test_deadline_admission_degenerates_to_fcfs_clock_free(setup):
    model, params = setup
    clock = _CountingClock()
    eng = Engine(model, params, BASE, time_fn=clock)
    for i in range(3):                    # batch tier: quota, no deadline
        eng.submit(_req(i, tenant="batch", arrival=float(i), base=10 * i))
    t_before = clock.t
    out = DeadlineAdmission().order(eng.sched)
    assert [r.rid for r in out] == [0, 1, 2]          # exact FCFS
    assert clock.t == t_before                        # zero clock reads
    assert "admission_reorders" not in eng.metrics.policy_counters


def test_quota_hold_exempts_single_oversized_request(setup):
    model, params = setup
    eng = Engine(model, params, BASE)
    pol = DeadlineAdmission()
    big = _req(0, n=60, tenant="batch", n_new=8)      # footprint 68 > 40
    assert request_footprint(big) == 68
    # idle tenant: oversized request still admits (progress exemption)
    assert pol.holds(eng.sched, big) is False
    # once anything of the tenant is in flight, the quota binds
    eng.sched._round_admits.append(_req(9, n=8, tenant="batch"))
    assert pol.holds(eng.sched, big) is True
    assert eng.metrics.policy_counters["quota_holds"] == 1
    # other tenants are untouched by batch's quota
    assert pol.holds(eng.sched, _req(5, tenant="gold")) is False


def test_quota_bounds_burst_head_of_line_damage(setup):
    """Regression: four long batch requests land at t=0, a gold request
    right behind them.  Without a quota the burst fills every slot and
    gold's first token waits out a full batch completion; with the quota
    the burst admits throttled and gold starts strictly earlier, at
    identical token streams."""
    model, params = setup

    def run(tenants):
        sc = dataclasses.replace(BASE, mode="sequential", tenants=tenants,
                                 n_pages=64)
        eng = Engine(model, params, sc, time_fn=_CountingClock())
        reqs = [_req(i, n=24, tenant="batch", arrival=0.0, n_new=8,
                     base=30 * i) for i in range(4)]
        reqs.append(_req(9, n=8, tenant="gold", arrival=0.001, base=200))
        eng.run(reqs, open_loop=True, max_steps=20_000)
        assert eng.metrics.summary()["n_done"] == 5
        return eng.metrics.req(9).ttft, [r.out_tokens for r in reqs]

    quota = (TIERS[0], TenantTier("batch", quota_tokens=64))
    no_quota = (TIERS[0], TenantTier("batch"))
    ttft_q, toks_q = run(quota)
    ttft_nq, toks_nq = run(no_quota)
    assert ttft_q < ttft_nq                 # the quota caps the damage
    assert toks_q == toks_nq                # ordering-only: same streams


# ----------------------------------------------------- preempt victims ----
def test_deadline_preempt_spares_tight_slack_victim(setup):
    """Latest arrival is the gold request with the tightest deadline:
    ``latest`` evicts it, ``deadline`` spares it and takes the
    infinite-slack batch request instead."""
    model, params = setup
    eng = Engine(model, params, BASE, time_fn=_CountingClock())
    batch = _req(0, tenant="batch", arrival=0.0, base=10)
    gold = _req(1, tenant="gold", arrival=1.0, base=30)
    for r in (batch, gold):
        eng.alloc.alloc(r.rid, 2)
        eng.metrics.req(r.rid)
    cands = [("slot", 0, batch, 8), ("slot", 1, gold, 8)]
    assert LatestPreempt().select(list(cands), eng) == ("slot", 1)
    assert DeadlinePreempt().select(list(cands), eng) == ("slot", 0)
    assert eng.metrics.policy_counters["deadline_spared_preemptions"] == 1


def test_deadline_preempt_tbt_binds_after_first_token(setup):
    """Once a request has emitted tokens its binding deadline switches
    to TBT: the decoding gold request with a stale last token becomes
    urgent, and the still-prefilling one (TTFT slack ahead) is evicted."""
    model, params = setup
    clock = _CountingClock()
    tight = dataclasses.replace(
        BASE, tenants=(TenantTier("gold", ttft_target=0.05,
                                  tbt_target=0.02), TIERS[1]))
    eng = Engine(model, params, tight, time_fn=clock)
    decoding = _req(0, tenant="gold", arrival=0.0, base=10)
    prefilling = _req(1, tenant="gold", arrival=0.4, base=30)
    for r in (decoding, prefilling):
        eng.alloc.alloc(r.rid, 2)
    m = eng.metrics.req(decoding.rid)
    m.t_first_token = 0.01
    m.token_times = [0.01]                 # stale: TBT deadline 0.03
    eng.metrics.req(prefilling.rid)        # TTFT deadline 0.4 + 0.05
    clock.t = 0.42                         # prefilling has more slack
    assert DeadlinePreempt().select(
        [("slot", 0, decoding, 8), ("slot", 1, prefilling, 8)],
        eng) == ("slot", 1)


def test_deadline_preempt_degenerates_without_deadlines(setup):
    """All-infinite slack: the choice falls back to the cache-aware
    fraction and then latest — and reads the clock zero times."""
    model, params = setup
    clock = _CountingClock()
    eng = Engine(model, params, BASE, time_fn=clock)
    reqs = [_req(i, tenant="batch", arrival=float(i), base=20 * i)
            for i in range(3)]
    for r in reqs:
        eng.alloc.alloc(r.rid, 2)
        eng.metrics.req(r.rid)
    cands = [("slot", i, r, 8) for i, r in enumerate(reqs)]
    t_before = clock.t
    assert (DeadlinePreempt().select(list(cands), eng)
            == LatestPreempt().select(list(cands), eng) == ("slot", 2))
    assert clock.t == t_before
    assert "deadline_spared_preemptions" not in eng.metrics.policy_counters


# ------------------------------------------------- no-deadline identity ---
def _mixed_tenant_reqs(vocab, seed=0):
    rng = np.random.RandomState(seed)
    prompts = [list(rng.randint(2, vocab, size=rng.randint(8, 18)))
               for _ in range(6)]
    return [Request(rid=i, prompt=p,
                    sampling=SamplingParams(max_new_tokens=6),
                    slo=SLOParams(tenant="batch" if i % 2 else "default"))
            for i, p in enumerate(prompts)]


def test_no_deadline_bit_identity_across_modes(setup):
    """Deadline policies + quota'd tiers but zero deadlines anywhere:
    every mode's greedy streams must match the fcfs/latest sequential
    oracle token for token, under the step sanitizer (which runs the
    tenant-quota check every step)."""
    model, params = setup
    vocab = model.cfg.vocab_size
    oracle_serve = dataclasses.replace(
        BASE, mode="sequential", n_pages=128, admission_policy="fcfs",
        preempt_policy="latest", tenants=(), enable_prefix_cache=False)
    oracle_reqs = _mixed_tenant_reqs(vocab)
    Engine(model, params, oracle_serve).run(oracle_reqs, max_steps=8000)
    oracle = [r.out_tokens for r in oracle_reqs]
    tiers = (TenantTier("batch", quota_tokens=60),)
    for mode in MODES:
        serve = dataclasses.replace(BASE, mode=mode, tenants=tiers,
                                    sanitize_level="step")
        eng = Engine(model, params, serve)
        reqs = _mixed_tenant_reqs(vocab)
        s = eng.run(reqs, max_steps=8000).summary()
        assert s["n_done"] == len(reqs), mode
        assert [r.out_tokens for r in reqs] == oracle, mode
        assert eng.alloc.n_allocated == 0 and eng.idle()


# ------------------------------------------------------ metrics rollups ---
def test_summary_rollups_and_attainment(setup):
    model, params = setup
    sc = dataclasses.replace(BASE, mode="sequential", n_pages=64,
                             tenants=(TenantTier("gold", ttft_target=50.0,
                                                 tbt_target=50.0),))
    eng = Engine(model, params, sc)
    eng.run([_req(0, tenant="gold"), _req(1, tenant="gold"), _req(2)],
            max_steps=8000)
    s = eng.metrics.summary()
    # wall-clock targets of 50s are unmissable on a test box
    assert s["slo_attained"] == 2 and s["slo_missed"] == 0
    assert s["slo_attainment"] == 1.0
    assert set(s["tenants"]) == {"gold", "default"}
    g = s["tenants"]["gold"]
    assert g["n_done"] == 2 and g["slo_attainment"] == 1.0
    assert g["ttft_p99"] >= g["ttft_p50"] > 0


def test_single_tenant_summary_shape_unchanged(setup):
    """No tiers, no SLOs: the rollup dict stays empty and nothing is
    judged — existing summary consumers see byte-identical shapes."""
    model, params = setup
    sc = dataclasses.replace(BASE, mode="sequential", n_pages=64,
                             tenants=(), admission_policy="fcfs",
                             preempt_policy="latest")
    eng = Engine(model, params, sc)
    eng.run([_req(0), _req(1)], max_steps=8000)
    s = eng.metrics.summary()
    assert s["tenants"] == {}
    assert s["slo_attained"] == 0 and s["slo_missed"] == 0
    assert s["slo_attainment"] is None


# ------------------------------------------------- sanitizer mutation ----
def test_tenant_quota_sanitizer_catches_disabled_hold(setup, monkeypatch):
    """Mutation proof: neuter the quota hold and the step sanitizer's
    ``tenant_quota`` check must fail the run (two batch requests over
    the 40-token quota in flight together)."""
    model, params = setup
    monkeypatch.setattr(DeadlineAdmission, "holds",
                        lambda self, sched, req: False)
    sc = dataclasses.replace(BASE, mode="sequential", n_pages=64,
                             sanitize_level="step")
    eng = Engine(model, params, sc)
    reqs = [_req(i, n=24, tenant="batch", n_new=8, base=30 * i)
            for i in range(3)]
    with pytest.raises(InvariantViolation) as e:
        eng.run(reqs, max_steps=8000)
    assert e.value.invariant == "tenant_quota"


# -------------------------------------------------- hypothesis sweep ----
# the rest of this module must not skip when hypothesis is absent, so
# only this arm is gated (module-level importorskip would drop it all)
try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile(
        "ci", max_examples=20, deadline=None, derandomize=True,
        database=None, print_blob=False)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(**kw):                                 # no-op placeholders
        return lambda fn: fn

    class settings:                                  # type: ignore[no-redef]
        def __init__(self, **kw):
            pass

        def __call__(self, fn):
            return fn

    class st:                                        # type: ignore[no-redef]
        integers = sampled_from = staticmethod(lambda *a, **k: None)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@given(seed=st.integers(0, 40), quota=st.integers(24, 120),
       mode=st.sampled_from(MODES))
@settings(max_examples=20, deadline=None)
def test_random_tenant_interleavings_stay_oracle_exact(setup, seed, quota,
                                                       mode):
    """Any tenant mix x quota x mode: deadline policies without deadlines
    never change a token, under the step sanitizer on a pressured pool."""
    model, params = setup
    vocab = model.cfg.vocab_size
    oracle_serve = dataclasses.replace(
        BASE, mode="sequential", n_pages=128, admission_policy="fcfs",
        preempt_policy="latest", tenants=(), enable_prefix_cache=False)
    oracle_reqs = _mixed_tenant_reqs(vocab, seed)
    Engine(model, params, oracle_serve).run(oracle_reqs, max_steps=8000)
    oracle = [r.out_tokens for r in oracle_reqs]
    serve = dataclasses.replace(
        BASE, mode=mode, sanitize_level="step",
        tenants=(TenantTier("batch", quota_tokens=quota),))
    eng = Engine(model, params, serve)
    reqs = _mixed_tenant_reqs(vocab, seed)
    s = eng.run(reqs, max_steps=8000).summary()
    assert s["n_done"] == len(reqs)
    assert [r.out_tokens for r in reqs] == oracle
