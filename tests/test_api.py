"""The vLLM-shaped request/response API surface.

Per-request ``SamplingParams`` honored identically in every engine mode,
``TokenEvent`` streams well-ordered, ``RequestOutput`` polling, open-loop
arrivals respecting timestamps, and seeded sampling independent of batch
composition — plus regressions for the arrival-sentinel and slot-invariant
fixes.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams

ARCH = "qwen3-0.6b"
MODES = ["sequential", "splitwiser", "splitwiser_mps"]
SERVE = ServeConfig(mode="sequential", max_batch=4, page_size=4, n_pages=128,
                    max_pages_per_seq=16, prefill_chunk=4, n_streams=2)


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [list(rng.randint(2, model.cfg.vocab_size,
                                size=rng.randint(5, 18))) for _ in range(4)]
    return model, params, prompts


def _mixed_requests(prompts):
    """One batch, four different sampling policies."""
    policies = [
        SamplingParams(max_new_tokens=6),                          # greedy
        SamplingParams(max_new_tokens=8, temperature=0.8, seed=7),
        SamplingParams(max_new_tokens=5, temperature=1.0, top_k=3, seed=9),
        SamplingParams(max_new_tokens=7, temperature=0.9, top_p=0.8, seed=3),
    ]
    return [Request(rid=i, prompt=list(p), sampling=policies[i])
            for i, p in enumerate(prompts)]


# ------------------------------------------------- per-request sampling ----
def test_per_request_params_agree_across_modes(setup):
    """A heterogeneous batch (greedy + temperature + top-k + top-p, mixed
    budgets) must produce the same per-request tokens in every mode."""
    model, params, prompts = setup
    per_mode = {}
    for mode in MODES:
        eng = Engine(model, params, dataclasses.replace(SERVE, mode=mode))
        reqs = _mixed_requests(prompts)
        eng.run(reqs, max_steps=1000)
        per_mode[mode] = [r.out_tokens for r in reqs]
        for r in reqs:
            assert len(r.out_tokens) == r.sampling.max_new_tokens
    assert per_mode["sequential"] == per_mode["splitwiser"]
    assert per_mode["sequential"] == per_mode["splitwiser_mps"]


def test_seeded_sampling_independent_of_batch_composition(setup):
    """(seed, rid, pos)-derived streams: a request's sampled tokens don't
    change when other requests share (or leave) the batch."""
    model, params, prompts = setup
    sp = SamplingParams(max_new_tokens=6, temperature=1.0, seed=5)
    eng = Engine(model, params, SERVE)
    alone = Request(rid=2, prompt=list(prompts[2]), sampling=sp)
    eng.run([alone], max_steps=1000)
    eng = Engine(model, params, dataclasses.replace(SERVE,
                                                    mode="splitwiser_mps"))
    reqs = _mixed_requests(prompts)
    reqs[2] = Request(rid=2, prompt=list(prompts[2]), sampling=sp)
    eng.run(reqs, max_steps=1000)
    assert reqs[2].out_tokens == alone.out_tokens


def test_seed_changes_sampled_tokens(setup):
    model, params, prompts = setup
    outs = []
    for seed in (0, 1):
        eng = Engine(model, params, SERVE)
        r = Request(rid=0, prompt=list(prompts[0]),
                    sampling=SamplingParams(max_new_tokens=8, temperature=1.0,
                                            seed=seed))
        eng.run([r], max_steps=1000)
        outs.append(r.out_tokens)
    assert outs[0] != outs[1]


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="max_new_tokens"):
        SamplingParams(max_new_tokens=0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)


# ------------------------------------------------------- events/outputs ----
@pytest.mark.parametrize("mode", MODES)
def test_stream_event_ordering(setup, mode):
    model, params, prompts = setup
    eng = Engine(model, params, dataclasses.replace(SERVE, mode=mode))
    events = list(eng.stream(_mixed_requests(prompts), max_steps=1000))
    outs = {o.rid: o for o in eng.poll()}
    assert [e.t for e in events] == sorted(e.t for e in events)
    by_rid = {}
    for e in events:
        by_rid.setdefault(e.rid, []).append(e)
    assert set(by_rid) == set(outs)
    for rid, evs in by_rid.items():
        assert [e.index for e in evs] == list(range(len(evs)))
        assert [e.first for e in evs] == [True] + [False] * (len(evs) - 1)
        assert [e.finish_reason for e in evs[:-1]] == [None] * (len(evs) - 1)
        assert evs[-1].finish_reason in ("length", "stop")
        assert [e.token for e in evs] == outs[rid].tokens
        assert [e.t for e in evs] == outs[rid].token_times


def test_poll_drains_once(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SERVE)
    eng.run(_mixed_requests(prompts), max_steps=1000)
    outs = eng.poll()
    assert len(outs) == len(prompts)
    assert eng.poll() == []
    for o in outs:
        assert o.ttft is not None and o.ttft >= 0
        assert o.e2e >= 0 and o.t_done >= o.arrival
        assert len(o.token_times) == len(o.tokens)


def test_stop_token_finish_reason(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SERVE)
    r = Request(rid=0, prompt=list(prompts[0]),
                sampling=SamplingParams(max_new_tokens=5))
    eng.run([r], max_steps=1000)
    first = r.out_tokens[0]
    eng = Engine(model, params, SERVE)
    r2 = Request(rid=0, prompt=list(prompts[0]),
                 sampling=SamplingParams(max_new_tokens=5,
                                         stop_token_ids=(first,)))
    eng.run([r2], max_steps=1000)
    (out,) = eng.poll()
    assert out.tokens == [first]
    assert out.finish_reason == "stop"


def test_step_returns_events(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SERVE)
    eng.submit(Request(rid=0, prompt=list(prompts[0]),
                       sampling=SamplingParams(max_new_tokens=3)))
    all_events = []
    for _ in range(100):
        if eng.idle():
            break
        all_events.extend(eng.step())
    assert [e.index for e in all_events] == [0, 1, 2]


# ----------------------------------------------------- open-loop arrivals --
def test_open_loop_respects_arrival_timestamps(setup):
    model, params, prompts = setup
    offsets = [0.0, 0.3, 0.6, 0.9]
    eng = Engine(model, params, SERVE)
    reqs = [Request(rid=i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=3), arrival=offsets[i])
            for i, p in enumerate(prompts)]
    m = eng.run(reqs, open_loop=True, max_steps=2000)
    assert m.summary()["n_done"] == len(prompts)
    t0 = min(m.req(i).arrival for i in range(len(prompts)))
    for i, off in enumerate(offsets):
        r = m.req(i)
        assert r.arrival == pytest.approx(t0 + off)   # offsets preserved
        assert r.t_first_token >= r.arrival           # no time travel
    admit_t = {e["rid"]: e["t"] for e in m.sched_events
               if e["event"] == "admit"}
    for i in range(len(prompts)):
        assert admit_t[i] >= m.req(i).arrival


def test_open_loop_matches_closed_loop_tokens(setup):
    """Arrival timing shifts latency, never tokens (greedy)."""
    model, params, prompts = setup
    eng = Engine(model, params, SERVE)
    closed = _mixed_requests(prompts)
    eng.run(closed, max_steps=1000)
    eng = Engine(model, params, SERVE)
    opened = _mixed_requests(prompts)
    for i, r in enumerate(opened):
        r.arrival = 0.05 * i
    eng.run(opened, open_loop=True, max_steps=2000)
    assert [r.out_tokens for r in opened] == [r.out_tokens for r in closed]


def test_submit_is_legal_mid_run(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SERVE)
    eng.submit(Request(rid=0, prompt=list(prompts[0]),
                       sampling=SamplingParams(max_new_tokens=4)))
    eng.step()                                   # engine is now mid-run
    eng.submit(Request(rid=1, prompt=list(prompts[1]),
                       sampling=SamplingParams(max_new_tokens=4)))
    m = eng.run([], max_steps=1000)              # drain both
    assert m.summary()["n_done"] == 2
    assert {o.rid for o in eng.poll()} == {0, 1}


def test_submit_preserves_explicit_zero_arrival(setup):
    """Regression: `arrival or now()` treated an explicit 0.0 as unset."""
    model, params, prompts = setup
    eng = Engine(model, params, SERVE)
    r = Request(rid=0, prompt=list(prompts[0]),
                sampling=SamplingParams(max_new_tokens=2), arrival=0.0)
    eng.submit(r)
    assert r.arrival == 0.0
    assert eng.metrics.req(0).arrival == 0.0
    r2 = Request(rid=1, prompt=list(prompts[1]),
                 sampling=SamplingParams(max_new_tokens=2))
    eng.submit(r2)
    assert r2.arrival is not None and r2.arrival > 0.0   # stamped at submit


# -------------------------------------------------------- config / slots ---
def test_unknown_mode_rejected_at_config():
    with pytest.raises(ValueError, match="supported modes"):
        ServeConfig(mode="splitwise")
    with pytest.raises(ValueError, match="supported modes"):
        dataclasses.replace(SERVE, mode="mp2")


def test_sequential_admission_never_overfills_slots(setup):
    """Admission is bounded by free decode slots: with max_batch=2 and 6
    requests, active slots never exceed 2 and no prefill batch is larger
    than the free-slot count (the `_emit_first_token` invariant)."""
    model, params, prompts = setup
    serve = dataclasses.replace(SERVE, max_batch=2)
    eng = Engine(model, params, serve)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=list(prompts[i % len(prompts)]),
                           sampling=SamplingParams(max_new_tokens=4)))
    orig = eng._do_full_prefill

    def spy(reqs):
        assert len(reqs) <= sum(s is None for s in eng.slots)
        return orig(reqs)

    eng._do_full_prefill = spy
    for _ in range(500):
        if eng.idle():
            break
        eng.step()
        assert sum(s is not None for s in eng.slots) <= 2
    assert eng.metrics.summary()["n_done"] == 6


def test_overfull_slots_raise_clear_invariant_error(setup):
    """If the invariant ever breaks, the error must say so instead of the
    seed's bare `ValueError: None is not in list`."""
    model, params, prompts = setup
    eng = Engine(model, params, SERVE)
    r = Request(rid=99, prompt=list(prompts[0]),
                sampling=SamplingParams(max_new_tokens=4))
    eng.submit(r)
    eng.slots = [object()] * len(eng.slots)      # simulate the broken state
    with pytest.raises(RuntimeError, match="slot invariant"):
        eng._emit_first_token(r, tok=1, seq_len=4, t=0.0)
