"""Runtime sanitizer: injected-corruption proofs + clean-run coverage.

Each corruption test deliberately breaks one cross-module invariant the
way a real bug would — a leaked refcount, a double-freed page, an
orphaned trie node, an under-budgeted admission — and asserts the
sanitizer raises :class:`InvariantViolation` *naming that invariant*.
This is mutation-style evidence the checks are live, not vacuous: if a
check regresses to a no-op, its injection test fails.

The clean-run tests drive all three engine modes under
``sanitize_level="step"`` on an oversubscribed pool (preemption +
prefix sharing + COW all firing) and require zero violations — the
contract holds on every real path, and the checker actually ran.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.analysis.invariants import InvariantViolation, verify_state
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.core.kv_cache import PageAllocator
from repro.core.prefix_cache import PrefixCache
from repro.core.scheduler import Scheduler

PS = 4


def _pair():
    cache = PrefixCache(PS)
    alloc = PageAllocator(16, PS, cache=cache)
    return alloc, cache


# ------------------------------------------------- injected corruption ----
def test_clean_state_passes():
    alloc, cache = _pair()
    pages = alloc.alloc(1, 3)
    cache.insert(list(range(2 * PS)), pages[:2])
    verify_state(alloc, cache)
    alloc.free(1)
    verify_state(alloc, cache)


def test_leaked_refcount_detected():
    alloc, cache = _pair()
    pages = alloc.alloc(1, 2)
    alloc._ref[pages[0]] += 1          # inject: refcount without an owner
    with pytest.raises(InvariantViolation) as e:
        verify_state(alloc, cache)
    assert e.value.invariant == "refcount_honesty"


def test_double_free_detected():
    alloc, cache = _pair()
    pages = alloc.alloc(1, 2)
    alloc.free(1)
    alloc._free.append(alloc._free[-1])   # inject: page freed twice
    with pytest.raises(InvariantViolation) as e:
        verify_state(alloc, cache)
    assert e.value.invariant == "page_conservation"
    assert "double free" in str(e.value)
    del pages


def test_page_leak_detected():
    alloc, cache = _pair()
    alloc._free.pop()                  # inject: page vanishes entirely
    with pytest.raises(InvariantViolation) as e:
        verify_state(alloc, cache)
    assert e.value.invariant == "page_conservation"


def test_orphaned_trie_node_detected():
    alloc, cache = _pair()
    pages = alloc.alloc(1, 2)
    cache.insert(list(range(2 * PS)), pages)    # parent -> child chain
    parent = cache._by_page[pages[0]]
    cache._evict(parent)               # inject: child's parent vanishes
    cache.orphaned_shared.discard(pages[0])
    with pytest.raises(InvariantViolation) as e:
        verify_state(alloc, cache)
    assert e.value.invariant == "trie_structure"
    assert "orphaned" in str(e.value)


def test_uncached_shared_page_detected():
    alloc, cache = _pair()
    (page,) = alloc.alloc(1, 1)
    # inject: a second request maps the page outside the cache contract
    # (refcounts stay honest, but no COW guard can know it's shared)
    alloc._owned[2] = [page]
    alloc._ref[page] += 1
    with pytest.raises(InvariantViolation) as e:
        verify_state(alloc, cache)
    assert e.value.invariant == "cow_exclusivity"


def test_reclaimable_while_referenced_detected():
    alloc, cache = _pair()
    pages = alloc.alloc(1, 1)
    cache.insert(list(range(PS)), pages)
    # inject: park a still-referenced cached page as reclaimable — a
    # strip would yank it out from under its live reader (the page now
    # sits in two pools at once, so conservation flags it)
    cache.on_release(pages[0])
    with pytest.raises(InvariantViolation) as e:
        verify_state(alloc, cache)
    assert e.value.invariant == "page_conservation"


def test_violation_carries_state_dump():
    alloc, cache = _pair()
    pages = alloc.alloc(7, 2)
    alloc._ref[pages[0]] += 1
    with pytest.raises(InvariantViolation) as e:
        verify_state(alloc, cache)
    exc = e.value
    assert exc.invariant == "refcount_honesty"
    assert exc.state["allocator"]["n_pages"] == 16
    assert "7" in exc.state["allocator"]["owned"]
    assert "state dump" in str(exc)


# ------------------------------------------------------ engine wiring ----
ARCH = "qwen3-0.6b"
MODES = ["sequential", "splitwiser", "splitwiser_mps"]

# oversubscribed: 4 requests each growing to ~7 pages vs 19 usable pages,
# with the prefix cache on so sharing/reclaim/COW paths all run checked
SMALL = ServeConfig(max_batch=4, page_size=4, n_pages=20,
                    max_pages_per_seq=12, prefill_chunk=4, n_streams=2,
                    enable_prefix_cache=True, sanitize_level="step")


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    shared = list(rng.randint(2, model.cfg.vocab_size, size=8))
    prompts = [shared + list(rng.randint(2, model.cfg.vocab_size, size=4))
               for _ in range(4)]
    return model, params, prompts


def _requests(prompts, n_new=12):
    return [Request(rid=i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=n_new))
            for i, p in enumerate(prompts)]


@pytest.mark.parametrize("mode", MODES)
def test_clean_run_under_step_sanitizer(setup, mode):
    model, params, prompts = setup
    eng = Engine(model, params, dataclasses.replace(SMALL, mode=mode))
    m = eng.run(_requests(prompts), max_steps=4000)
    s = m.summary()
    assert s["n_done"] == len(prompts)
    assert eng.sanitizer is not None and eng.sanitizer.n_checks > 0


def test_sanitize_off_has_no_checker(setup):
    model, params, prompts = setup
    eng = Engine(model, params,
                 dataclasses.replace(SMALL, sanitize_level="off"))
    assert eng.sanitizer is None
    m = eng.run(_requests(prompts, n_new=4), max_steps=4000)
    assert m.summary()["n_done"] == len(prompts)


def test_underbudgeted_admission_detected(setup, monkeypatch):
    """Budget honesty end-to-end: make the scheduler charge zero pages
    for every admission — prefill consumption then exceeds the recorded
    budget and the first-token hook must flag it."""
    model, params, prompts = setup
    monkeypatch.setattr(
        Scheduler, "admission_pages",
        lambda self, req, free_cached=0, cow_extra=0, n_hit=0: 0)
    eng = Engine(model, params, SMALL)
    with pytest.raises(InvariantViolation) as e:
        eng.run(_requests(prompts), max_steps=4000)
    assert e.value.invariant == "scheduler_budget"


def test_pressure_run_exercises_preempt_promises(setup):
    """The oversubscribed pool actually preempts, so the differential
    preempt/resume checker ran on real scheduler paths — and stayed
    silent."""
    model, params, prompts = setup
    eng = Engine(model, params, SMALL)
    m = eng.run(_requests(prompts), max_steps=4000)
    assert m.summary()["n_done"] == len(prompts)
    assert m.n_preempt_events > 0           # the checker had work to do
    assert not eng.sanitizer._preempt_snaps  # every promise was settled


def _promised_chain(eng):
    """Build the differential checker's precondition by hand: rid 1 owns
    a cached 2-page chain that rid 2 also references, so at preemption
    both pages are promised to survive rid 1's free."""
    toks = list(range(100, 100 + 2 * PS))
    pages = eng.alloc.alloc(1, 2)
    eng.prefix_cache.insert(toks, pages)
    eng.alloc.share(2, pages)               # the external reference
    req = Request(rid=1, prompt=list(toks),
                  sampling=SamplingParams(max_new_tokens=4))
    return req, toks, pages


def test_resume_recompute_of_promised_page_detected(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SMALL)
    req, toks, pages = _promised_chain(eng)
    eng.sanitizer.note_preempt(req, len(toks))
    eng.alloc.free(1)                       # the scheduler's eviction
    # inject: the resume recomputes instead of remapping — the promised
    # pages are still cached, so the empty match is a regression
    with pytest.raises(InvariantViolation) as e:
        eng.sanitizer.note_resume(req, [])
    assert e.value.invariant == "preempt_resume"
    assert "recomputed promised page" in str(e.value)


def test_resume_without_ownership_detected(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SMALL)
    req, toks, pages = _promised_chain(eng)
    eng.sanitizer.note_preempt(req, len(toks))
    eng.alloc.free(1)
    # inject: resume claims the match but never re-acquired references
    with pytest.raises(InvariantViolation) as e:
        eng.sanitizer.note_resume(req, list(pages))
    assert e.value.invariant == "preempt_resume"
    assert "does not own" in str(e.value)


def test_resume_remap_settles_promise(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SMALL)
    req, toks, pages = _promised_chain(eng)
    eng.sanitizer.note_preempt(req, len(toks))
    eng.alloc.free(1)
    eng.alloc.share(1, pages)               # the honest resume remap
    eng.sanitizer.note_resume(req, list(pages))
    assert 1 not in eng.sanitizer._preempt_snaps


def test_promise_lapses_on_eviction(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SMALL)
    req, toks, pages = _promised_chain(eng)
    eng.sanitizer.note_preempt(req, len(toks))
    eng.alloc.free(1)
    eng.alloc.free(2)                       # chain parks reclaimable...
    while eng.prefix_cache.pop_reclaimable() is not None:
        pass                                # ...and pressure strips it
    assert not any(eng.prefix_cache.is_cached(p) for p in pages)
    eng.sanitizer.note_resume(req, [])      # recompute is legitimate now


def test_lossy_resume_match_detected_end_to_end(setup, monkeypatch):
    """Integration proof: regress the resume-side prefix match (the
    engine recomputes what resume_safe_pages promised to remap) and the
    differential checker must catch it on a real preempt/resume cycle."""
    model, params, prompts = setup
    orig = Engine._map_cached

    def lossy(self, req):
        if (self.sanitizer is not None
                and req.rid in self.sanitizer._preempt_snaps):
            # resumes recompute from scratch; first admissions unaffected
            self.sanitizer.note_resume(req, [])
            return 0
        return orig(self, req)

    monkeypatch.setattr(Engine, "_map_cached", lossy)
    eng = Engine(model, params, SMALL)
    with pytest.raises(InvariantViolation) as e:
        eng.run(_requests(prompts), max_steps=4000)
    assert e.value.invariant == "preempt_resume"


# --------------------------------------------- int8 scale sidecar ----
SMALL_I8 = dataclasses.replace(SMALL, kv_dtype="int8")


def _int8_engine_mid_run(setup):
    """An int8 engine a few steps into the SMALL workload, with live
    slots/streams whose pages carry scale entries."""
    model, params, prompts = setup
    eng = Engine(model, params, SMALL_I8)
    for r in _requests(prompts):
        eng.submit(r)
    while not any(eng.slots) and not eng.idle():
        eng.step()
    return eng


def test_int8_clean_run_under_step_sanitizer(setup):
    model, params, prompts = setup
    eng = Engine(model, params, SMALL_I8)
    m = eng.run(_requests(prompts), max_steps=4000)
    assert m.summary()["n_done"] == len(prompts)
    assert eng.sanitizer.n_checks > 0
    # at idle every surviving entry belongs to a parked cached page (still
    # valid quantized contents, still serving hits); none leaked elsewhere
    assert all(eng.prefix_cache.is_cached(p) for p in eng.kv_quant.entries)
    assert m.summary()["n_quant_pages"] > 0


def test_missing_scale_entry_detected(setup):
    eng = _int8_engine_mid_run(setup)
    slot = next(s for s in eng.slots if s is not None)
    page = eng.alloc.owned(slot.req.rid)[0]
    del eng.kv_quant.entries[page]          # inject: committed page lost
    with pytest.raises(InvariantViolation) as e:  # its scale sidecar
        eng.sanitizer.check_now()
    assert e.value.invariant == "scale_sidecar"
    assert "no scale entry" in str(e.value)


def test_duplicate_scale_entry_detected(setup):
    eng = _int8_engine_mid_run(setup)
    page = next(iter(eng.kv_quant.entries))
    eng.kv_quant.entries[page] = 2          # inject: double-quantized page
    with pytest.raises(InvariantViolation) as e:
        eng.sanitizer.check_now()
    assert e.value.invariant == "scale_sidecar"
    assert "exactly one" in str(e.value)


def test_freed_page_scale_entry_detected(setup):
    eng = _int8_engine_mid_run(setup)
    page = eng.alloc._free[0]
    eng.kv_quant.entries[page] = 1          # inject: entry outlived its page
    with pytest.raises(InvariantViolation) as e:
        eng.sanitizer.check_now()
    assert e.value.invariant == "scale_sidecar"
    assert "free list" in str(e.value)


def test_pool_byte_drift_detected(setup):
    eng = _int8_engine_mid_run(setup)
    eng.metrics.kv_pool_bytes += 1          # inject: byte accounting drift
    with pytest.raises(InvariantViolation) as e:
        eng.sanitizer.check_now()
    assert e.value.invariant == "scale_sidecar"
    assert "conserve" in str(e.value)


def test_step_corruption_caught_at_the_step(setup):
    """A corruption planted mid-run surfaces at the next step boundary,
    with the event-ring tail attached for post-mortem."""
    model, params, prompts = setup
    eng = Engine(model, params, SMALL)
    for r in _requests(prompts):
        eng.submit(r)
    eng.step()
    live_rids = [rid for rid in eng.alloc._owned if eng.alloc._owned[rid]]
    page = eng.alloc._owned[live_rids[0]][0]
    eng.alloc._ref[page] += 1          # inject mid-run
    with pytest.raises(InvariantViolation) as e:
        eng.step()
    assert e.value.invariant == "refcount_honesty"
    assert e.value.events                  # post-mortem trace attached
    assert any(ev.get("event") == "admit" for ev in e.value.events)
