"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import family_batch, reduced_model
from repro.configs import TrainConfig
from repro.configs.registry import ASSIGNED
from repro.train.trainer import init_state, make_train_step


@pytest.mark.parametrize("arch", ASSIGNED + ["opt-125m"])
def test_forward_shapes_and_finite(arch):
    model = reduced_model(arch)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 16
    batch = family_batch(cfg, B, T)
    logits, aux = model.train_logits(params, batch)
    T_out = logits.shape[1]
    assert logits.shape[0] == B and logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all()), arch
    assert bool(jnp.isfinite(jnp.asarray(aux)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    model = reduced_model(arch)
    cfg = model.cfg
    tcfg = TrainConfig(global_batch=2, seq_len=16, total_steps=2,
                       ckpt_dir="/tmp/x", remat=False)
    step = jax.jit(make_train_step(model, tcfg))
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    batch = family_batch(cfg, 2, 16)
    if cfg.family == "vlm":
        T = 16
    batch["labels"] = batch["tokens"]
    state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"])), arch
    assert float(m["loss"]) > 0
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.isfinite(p0).all())
