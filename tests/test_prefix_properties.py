"""Hypothesis property tests: PrefixCache + PageAllocator invariants.

Random interleavings of the engine's cache lifecycle — insert, match,
share, alloc (with reclaim), copy-on-write, free — must never violate:

* refcounts stay positive (zero-ref entries leave the table entirely);
* page conservation: every usable page is in exactly one of
  {free list, reclaimable pool, live-referenced}, so
  ``reclaimable + live == allocated-from-free-list`` and
  ``n_free + len(_ref) == n_pages - 1``;
* trie structure: parent-before-child (every non-root node's parent is
  live and was created first) and consistent child/descendant counts —
  a reclaimable-leaf pop never orphans a chain.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.kv_cache import OutOfPages, PageAllocator
from repro.core.policies import make_eviction
from repro.core.prefix_cache import PrefixCache

PS = 4


def _check_invariants(alloc: PageAllocator, cache: PrefixCache):
    # refcount >= 0 (entries are deleted at zero, so live ones are >= 1)
    assert all(c >= 1 for c in alloc._ref.values())
    # conservation: free list + reclaimable + live == usable pool, disjoint
    free = set(alloc._free)
    recl = set(cache._reclaimable)
    live = set(alloc._ref)
    assert not (free & recl) and not (free & live) and not (recl & live)
    assert len(free) + len(recl) + len(live) == alloc.n_pages - 1
    assert alloc.n_free == len(free) + len(recl)
    assert len(live) == alloc.n_allocated          # reclaimable + live split
    # ownership table matches the refcounts exactly
    counts = {}
    for pages in alloc._owned.values():
        for p in pages:
            counts[p] = counts.get(p, 0) + 1
    assert counts == alloc._ref
    # trie: parents live, created-before-child, consistent counts
    n_children = {}
    n_desc_leafward = {}
    for node in cache._nodes.values():
        if node.parent is not None:
            assert node.parent.key in cache._nodes       # parent-before-child
            assert node.parent.nid < node.nid
            assert node.depth == node.parent.depth + 1
            anc = node.parent
            while anc is not None:
                n_desc_leafward[anc.nid] = n_desc_leafward.get(anc.nid, 0) + 1
                anc = anc.parent
            n_children[node.parent.nid] = n_children.get(node.parent.nid, 0) + 1
        else:
            assert node.depth == 0
    for node in cache._nodes.values():
        assert node.n_children == n_children.get(node.nid, 0)
        assert node.n_desc == n_desc_leafward.get(node.nid, 0)
    # reclaimable nodes are cached, zero-ref
    for page, node in cache._reclaimable.items():
        assert cache._by_page[page] is node
        assert page not in alloc._ref


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_cache_lifecycle_interleavings_preserve_invariants(data):
    """Drive a random request lifecycle against a small pool: admissions
    match+share the trie then alloc the miss pages (stripping reclaimable
    leaves under pressure), writers COW shared/cached tail pages, and
    finishes insert committed full pages before freeing."""
    n_pages = data.draw(st.integers(6, 24))
    policy = make_eviction(data.draw(st.sampled_from(["lru", "fifo", "cost"])))
    cache = PrefixCache(PS, policy=policy)
    alloc = PageAllocator(n_pages, PS, cache=cache)
    # a tiny template pool makes prefix collisions (shared chains) common
    templates = [
        [data.draw(st.integers(0, 3)) for _ in range(PS * data.draw(st.integers(1, 4)))]
        for _ in range(3)
    ]
    live = {}          # rid -> token list backing its owned pages
    next_rid = 0
    for _ in range(data.draw(st.integers(1, 30))):
        op = data.draw(st.sampled_from(["admit", "finish", "write", "match"]))
        if op == "admit":
            t = data.draw(st.sampled_from(templates))
            tail = [data.draw(st.integers(0, 9)) for _ in range(
                data.draw(st.integers(0, 2 * PS)))]
            tokens = list(t) + tail
            rid = next_rid = next_rid + 1
            hit = cache.match(tokens)
            need = alloc.pages_needed(len(tokens)) - len(hit)
            if not alloc.can_alloc(need + len(hit)):
                continue            # admission rejected: no state change
            alloc.share(rid, hit)   # hits first, so they can't be reclaimed
            cache.touch(hit)        # out from under the request
            if need:
                alloc.alloc(rid, need)
            live[rid] = tokens
        elif op == "finish" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            tokens = live.pop(rid)
            n_full = len(tokens) // PS
            if n_full:
                cache.insert(tokens[: n_full * PS],
                             alloc.owned(rid)[:n_full])
            alloc.free(rid)
        elif op == "write" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            pos = data.draw(st.integers(0, max(len(live[rid]) - 1, 0)))
            try:
                alloc.prepare_write(rid, pos)
            except OutOfPages:
                pass    # legal refusal: COW needs a page and the pool is
                        # dry — the engine never reaches this (cached
                        # spans are capped below written positions), and
                        # the invariants must survive the partial failure
        elif op == "match":
            t = data.draw(st.sampled_from(templates))
            pages = cache.match(t)
            assert len(pages) <= len(t) // PS
        _check_invariants(alloc, cache)
    # drain everything: the pool must be whole again
    for rid in sorted(live):
        alloc.free(rid)
    _check_invariants(alloc, cache)
    assert alloc.n_free == alloc.n_pages - 1


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_reclaim_under_pressure_keeps_chains_intact(data):
    """Exhaust the pool so allocs strip reclaimable leaves: after every
    strip the surviving trie still satisfies parent-before-child, and a
    re-match of any template returns a (possibly shorter) *prefix* of
    its page chain — never a gapped one."""
    n_pages = data.draw(st.integers(8, 16))
    cache = PrefixCache(PS, policy=data.draw(
        st.sampled_from(["lru", "fifo", "cost"])))
    alloc = PageAllocator(n_pages, PS, cache=cache)
    templates = []
    rid = 0
    # fill the cache with a few chains, freeing each owner
    for _ in range(data.draw(st.integers(1, 4))):
        n = data.draw(st.integers(1, 3))
        tokens = [data.draw(st.integers(0, 2)) for _ in range(n * PS)]
        if not alloc.can_alloc(n):
            break
        rid += 1
        hit = cache.match(tokens)
        alloc.share(rid, hit)
        fresh = alloc.alloc(rid, n - len(hit)) if n - len(hit) else []
        cache.insert(tokens, hit + fresh)
        templates.append((tokens, cache.match(tokens)))
        alloc.free(rid)
    # hammer allocations until the pool (incl. reclaimable) is exhausted
    while alloc.can_alloc(1):
        rid += 1
        alloc.alloc(rid, 1)
        _check_invariants(alloc, cache)
        for tokens, chain in templates:
            got = cache.match(tokens)
            assert got == chain[: len(got)]      # always a prefix, no gaps
    assert cache.n_reclaimable == 0              # pressure drained the pool
