"""Hypothesis property tests: PrefixCache + PageAllocator invariants.

Random interleavings of the engine's cache lifecycle — insert (full and
partial-tail), match (page- and token-level), share, partial-page COW
(``cow_partial``), alloc (with reclaim), copy-on-write, free — must
never violate:

* refcounts stay positive (zero-ref entries leave the table entirely);
* page conservation: every usable page is in exactly one of
  {free list, reclaimable pool, live-referenced}, so
  ``reclaimable + live == allocated-from-free-list`` and
  ``n_free + len(_ref) == n_pages - 1``;
* trie structure: parent-before-child (every non-root node's parent is
  live and was created first), consistent child/descendant counts, and
  explicit child links mirroring the node table exactly — a
  reclaimable-leaf pop never orphans a chain;
* granularity: partial nodes (``n_valid < page_size``) are always
  leaves, and a token-level match never claims tokens beyond a node's
  valid span.
"""
import contextlib
import os

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.analysis.hooks import install_call_hooks
from repro.analysis.invariants import verify_state
from repro.core.kv_cache import OutOfPages, PageAllocator
from repro.core.policies import make_eviction
from repro.core.prefix_cache import PrefixCache

# "ci" profile (HYPOTHESIS_PROFILE=ci): fixed seed, no deadline — property
# tests cannot time out or flake on slow shared runners; locally the
# default profile keeps full randomized exploration.
settings.register_profile(
    "ci", max_examples=40, deadline=None, derandomize=True,
    database=None, print_blob=False)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))

PS = 4


def _check_invariants(alloc: PageAllocator, cache: PrefixCache):
    # The full allocator/trie contract — conservation, refcount honesty,
    # COW exclusivity, trie structure, reclaimable-pool consistency — now
    # lives in repro.analysis.invariants: these property tests drive
    # random lifecycle interleavings through the SAME checker the runtime
    # sanitizer (KVSanitizer) runs after engine steps, so a divergence
    # between the two can't creep in.  Raises InvariantViolation (with a
    # state dump) on any breach; hypothesis shrinks from there.
    verify_state(alloc, cache)
    # live/reclaimable split is a property-suite extra: n_allocated counts
    # referenced pages only
    assert len(alloc._ref) == alloc.n_allocated


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_cache_lifecycle_interleavings_preserve_invariants(data):
    """Drive a random request lifecycle against a small pool: admissions
    match+share the trie then alloc the miss pages (stripping reclaimable
    leaves under pressure), writers COW shared/cached tail pages, and
    finishes insert committed full pages before freeing."""
    n_pages = data.draw(st.integers(6, 24))
    policy = make_eviction(data.draw(st.sampled_from(["lru", "fifo", "cost"])))
    cache = PrefixCache(PS, policy=policy)
    alloc = PageAllocator(n_pages, PS, cache=cache)
    # sanitize_level="call" equivalent: every mutating alloc/cache call in
    # the random interleaving below is also invariant-checked at its own
    # exit, with the violation attributed to the exact call site
    hooks = install_call_hooks(alloc, cache)
    # a tiny template pool makes prefix collisions (shared chains) common
    templates = [
        [data.draw(st.integers(0, 3)) for _ in range(PS * data.draw(st.integers(1, 4)))]
        for _ in range(3)
    ]
    live = {}          # rid -> token list backing its owned pages
    next_rid = 0
    for _ in range(data.draw(st.integers(1, 30))):
        op = data.draw(st.sampled_from(["admit", "finish", "write", "match"]))
        if op == "admit":
            t = data.draw(st.sampled_from(templates))
            tail = [data.draw(st.integers(0, 9)) for _ in range(
                data.draw(st.integers(0, 2 * PS)))]
            tokens = list(t) + tail
            rid = next_rid = next_rid + 1
            hit, partial = cache.match_tokens(tokens)
            use_partial = (partial is not None
                           and alloc.pages_needed(len(tokens)) > len(hit)
                           and data.draw(st.booleans()))
            need = (alloc.pages_needed(len(tokens)) - len(hit)
                    - (1 if use_partial else 0))
            # budget like the scheduler: hits + misses + the COW copy,
            # plus the transient revive of an unreferenced donor
            extra = (1 + (0 if alloc.is_referenced(partial[0]) else 1)
                     if use_partial else 0)
            if not alloc.can_alloc(need + len(hit) + extra):
                continue            # admission rejected: no state change
            alloc.share(rid, hit)   # hits first, so they can't be reclaimed
            cache.touch(hit)        # out from under the request
            if use_partial:
                alloc.cow_partial(rid, partial[0])
                cache.touch([partial[0]])
            if need:
                alloc.alloc(rid, need)
            live[rid] = tokens
        elif op == "finish" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            tokens = live.pop(rid)
            n_full, rem = divmod(len(tokens), PS)
            if rem and data.draw(st.booleans()):
                # terminal insert at token granularity: the partial tail
                # page registers as a leaf (engine: cache_insert(final))
                cache.insert(tokens, alloc.owned(rid)[:n_full + 1],
                             allow_partial=True)
            elif n_full:
                cache.insert(tokens[: n_full * PS],
                             alloc.owned(rid)[:n_full])
            alloc.free(rid)
        elif op == "write" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            pos = data.draw(st.integers(0, max(len(live[rid]) - 1, 0)))
            # OutOfPages is a legal refusal: COW needs a page and the
            # pool may be dry — the engine never reaches this (cached
            # spans are capped below written positions), and the
            # invariants must survive the partial failure
            with contextlib.suppress(OutOfPages):
                alloc.prepare_write(rid, pos)
        elif op == "match":
            t = data.draw(st.sampled_from(templates))
            pages = cache.match(t)
            assert len(pages) <= len(t) // PS
            # token-level lookup: the partial continuation (if any) is a
            # strict sub-page span of a live cached page
            pages2, partial = cache.match_tokens(t)
            assert pages2 == pages
            if partial is not None:
                page, n = partial
                node = cache._by_page[page]
                assert 1 <= n <= min(node.n_valid,
                                     len(t) - len(pages) * PS)
                assert list(node.key[1][:n]) == list(
                    t[len(pages) * PS: len(pages) * PS + n])
        _check_invariants(alloc, cache)
    # drain everything: the pool must be whole again
    for rid in sorted(live):
        alloc.free(rid)
    _check_invariants(alloc, cache)
    assert alloc.n_free == alloc.n_pages - 1
    assert hooks.n_call_checks > 0           # the call tier actually ran


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_reclaim_under_pressure_keeps_chains_intact(data):
    """Exhaust the pool so allocs strip reclaimable leaves: after every
    strip the surviving trie still satisfies parent-before-child, and a
    re-match of any template returns a (possibly shorter) *prefix* of
    its page chain — never a gapped one."""
    n_pages = data.draw(st.integers(8, 16))
    cache = PrefixCache(PS, policy=data.draw(
        st.sampled_from(["lru", "fifo", "cost"])))
    alloc = PageAllocator(n_pages, PS, cache=cache)
    install_call_hooks(alloc, cache)         # call-tier checks ride along
    templates = []
    rid = 0
    # fill the cache with a few chains, freeing each owner
    for _ in range(data.draw(st.integers(1, 4))):
        n = data.draw(st.integers(1, 3))
        tokens = [data.draw(st.integers(0, 2)) for _ in range(n * PS)]
        if not alloc.can_alloc(n):
            break
        rid += 1
        hit = cache.match(tokens)
        alloc.share(rid, hit)
        fresh = alloc.alloc(rid, n - len(hit)) if n - len(hit) else []
        cache.insert(tokens, hit + fresh)
        templates.append((tokens, cache.match(tokens)))
        alloc.free(rid)
    # hammer allocations until the pool (incl. reclaimable) is exhausted
    while alloc.can_alloc(1):
        rid += 1
        alloc.alloc(rid, 1)
        _check_invariants(alloc, cache)
        for tokens, chain in templates:
            got = cache.match(tokens)
            assert got == chain[: len(got)]      # always a prefix, no gaps
    assert cache.n_reclaimable == 0              # pressure drained the pool
