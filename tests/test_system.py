"""End-to-end system behaviour: every engine mode must produce EXACTLY the
tokens a naive full-forward greedy loop produces, while tracking the
paper's metrics; plus phase-accounting sanity per mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request, SamplingParams
from repro.models import transformer as T

ARCH = "qwen3-0.6b"
N_NEW = 6


@pytest.fixture(scope="module")
def setup():
    model = reduced_model(ARCH)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(2, model.cfg.vocab_size,
                                size=rng.randint(5, 20))) for _ in range(6)]

    def naive(prompt):
        toks = list(prompt)
        for _ in range(N_NEW):
            lg, _ = T.train_logits(params, model.cfg,
                                   {"tokens": jnp.asarray([toks])})
            toks.append(int(lg[0, -1].argmax()))
        return toks[len(prompt):]

    oracle = [naive(p) for p in prompts]
    return model, params, prompts, oracle


@pytest.mark.parametrize("mode", ["sequential", "splitwiser", "splitwiser_mps"])
def test_mode_matches_oracle(setup, mode):
    model, params, prompts, oracle = setup
    serve = ServeConfig(mode=mode, max_batch=4, page_size=4, n_pages=128,
                        max_pages_per_seq=16, prefill_chunk=4, n_streams=2)
    eng = Engine(model, params, serve)
    reqs = [Request(rid=i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=N_NEW))
            for i, p in enumerate(prompts)]
    m = eng.run(reqs, max_steps=1000)
    assert [r.out_tokens for r in reqs] == oracle
    outs = {o.rid: o for o in eng.poll()}
    assert [outs[i].tokens for i in range(len(prompts))] == oracle
    assert all(o.finish_reason == "length" for o in outs.values())
    s = m.summary()
    assert s["n_done"] == len(prompts)
    assert s["finish_reasons"] == {"length": len(prompts)}
    assert s["throughput_tok_s"] > 0
    assert s["ttft"]["mean"] is not None and s["ttft"]["mean"] >= 0
    assert 0 < s["kv_usage_peak"] <= 1.0


def test_mode_step_kinds(setup):
    """sequential never emits mixed steps; splitwiser_mps only mixed."""
    model, params, prompts, oracle = setup
    for mode in ["sequential", "splitwiser_mps"]:
        serve = ServeConfig(mode=mode, max_batch=4, page_size=4, n_pages=128,
                            max_pages_per_seq=16, prefill_chunk=4, n_streams=2)
        eng = Engine(model, params, serve)
        reqs = [Request(rid=i, prompt=list(p),
                        sampling=SamplingParams(max_new_tokens=N_NEW))
                for i, p in enumerate(prompts)]
        eng.run(reqs, max_steps=1000)
        kinds = set(eng.metrics.step_kinds) - {"idle"}
        if mode == "sequential":
            assert kinds == {"prefill", "decode"}
        else:
            assert kinds == {"mixed"}


def test_mixed_batching_reduces_steps(setup):
    """The Splitwiser property: fused mode advances both phases per step
    -> strictly fewer engine steps than the time-sliced (no-MPS) mode on
    a mixed workload."""
    model, params, prompts, oracle = setup
    results = {}
    for mode in ["splitwiser", "splitwiser_mps"]:
        serve = ServeConfig(mode=mode, max_batch=4, page_size=4, n_pages=256,
                            max_pages_per_seq=32, prefill_chunk=4, n_streams=2)
        eng = Engine(model, params, serve)
        long_prompt = list(np.random.RandomState(7).randint(2, 200, size=64))
        reqs = [Request(rid=0, prompt=list(prompts[0]),
                        sampling=SamplingParams(max_new_tokens=20)),
                Request(rid=1, prompt=long_prompt,
                        sampling=SamplingParams(max_new_tokens=4))]
        eng.run(reqs, max_steps=1000)
        results[mode] = eng.metrics.n_steps
    assert results["splitwiser_mps"] < results["splitwiser"], results


def test_eos_termination(setup):
    """eos_id is a per-request SamplingParams knob, not engine state."""
    model, params, prompts, _ = setup
    serve = ServeConfig(mode="sequential", max_batch=4, page_size=4,
                        n_pages=128, max_pages_per_seq=16)
    eng0 = Engine(model, params, serve)
    r = Request(rid=0, prompt=list(prompts[0]),
                sampling=SamplingParams(max_new_tokens=5))
    eng0.run([r])
    first = r.out_tokens[0]
    eng = Engine(model, params, serve)
    r2 = Request(rid=0, prompt=list(prompts[0]),
                 sampling=SamplingParams(max_new_tokens=5, eos_id=first))
    # an eos-less request in the SAME batch keeps generating
    r3 = Request(rid=1, prompt=list(prompts[0]),
                 sampling=SamplingParams(max_new_tokens=5))
    eng.run([r2, r3])
    assert r2.out_tokens[0] == first and len(r2.out_tokens) == 1
    assert len(r3.out_tokens) == 5
    outs = {o.rid: o for o in eng.poll()}
    assert outs[0].finish_reason == "stop"
    assert outs[1].finish_reason == "length"
    assert eng.alloc.n_allocated == 0
