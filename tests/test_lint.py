"""Repo-specific lint (repro.analysis.lint): every rule proven live on a
seeded fixture, and the repo itself proven clean.

The fixture file below contains one deliberate instance of each bug
class the lint encodes; if a rule regresses to a no-op its finding
disappears and the test fails.  The clean-repo test is the same check CI
runs (``python -m repro.analysis.lint src/`` exiting zero).
"""
import subprocess
import sys
from pathlib import Path

from repro.analysis import lint

SRC = Path(__file__).resolve().parent.parent / "src"

FIXTURE = '''\
from dataclasses import dataclass


def helper(acc=[]):                       # RPR001 (function arg)
    return acc


@dataclass
class Request:
    sampling: object = object()           # RPR001 (the PR-3 bug class)


@dataclass
class ServeConfig:
    mode: str = "a"
    n_pages: int = 8                      # RPR003 (never validated)

    def __post_init__(self):
        if self.mode != "a":
            raise ValueError(self.mode)


@dataclass
class EngineMetrics:
    n_steps: int = 0
    n_hidden: int = 0                     # RPR005 (not in summary)

    def summary(self):
        return {"n_steps": self.n_steps}


def runtime_path(xs):
    assert xs, "no tokens"                # RPR002
    import jax.numpy as jnp
    out = []
    for x in xs:
        out.append(jnp.asarray(x))        # RPR004 (scoped to core/)
    return out
'''


def _write_fixture(tmp_path):
    # under a repro/core/ directory so the core-scoped RPR004 rule applies
    d = tmp_path / "repro" / "core"
    d.mkdir(parents=True)
    f = d / "seeded.py"
    f.write_text(FIXTURE)
    return f


def test_every_rule_fires_on_seeded_fixture(tmp_path):
    f = _write_fixture(tmp_path)
    findings = lint.lint_paths([str(f)])
    assert {x.code for x in findings} == {
        "RPR001", "RPR002", "RPR003", "RPR004", "RPR005"}
    # both mutable-default shapes (arg literal + dataclass call) are hit
    assert sum(1 for x in findings if x.code == "RPR001") == 2


def test_select_filters_rules(tmp_path):
    f = _write_fixture(tmp_path)
    findings = lint.lint_paths([str(f)], select=["RPR002"])
    assert findings and all(x.code == "RPR002" for x in findings)


def test_scope_suppresses_core_rule_outside_core(tmp_path):
    d = tmp_path / "repro" / "models"
    d.mkdir(parents=True)
    f = d / "seeded.py"
    f.write_text(FIXTURE)
    codes = {x.code for x in lint.lint_paths([str(f)])}
    assert "RPR004" not in codes          # jnp loops are legitimate there
    assert "RPR002" in codes              # unscoped rules still apply


def test_syntax_error_is_a_finding(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def f(:\n")
    findings = lint.lint_paths([str(f)])
    assert findings and findings[0].code == "RPR000"


def test_repo_src_is_clean():
    assert lint.lint_paths([str(SRC)]) == []


def test_cli_exit_codes(tmp_path):
    f = _write_fixture(tmp_path)
    env_src = str(SRC)
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", env_src],
        capture_output=True, text=True, env={"PYTHONPATH": env_src})
    assert clean.returncode == 0, clean.stdout + clean.stderr
    seeded = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(f)],
        capture_output=True, text=True, env={"PYTHONPATH": env_src})
    assert seeded.returncode == 1
    assert "RPR001" in seeded.stdout
