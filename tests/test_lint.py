"""Repo-specific lint (repro.analysis.lint): every rule proven live on a
seeded fixture, and the repo itself proven clean.

The fixture file below contains one deliberate instance of each bug
class the lint encodes; if a rule regresses to a no-op its finding
disappears and the test fails.  The clean-repo test is the same check CI
runs (``python -m repro.analysis.lint src/`` exiting zero).
"""
import subprocess
import sys
from pathlib import Path

from repro.analysis import lint

SRC = Path(__file__).resolve().parent.parent / "src"

FIXTURE = '''\
from dataclasses import dataclass


def helper(acc=[]):                       # RPR001 (function arg)
    return acc


@dataclass
class Request:
    sampling: object = object()           # RPR001 (the PR-3 bug class)


@dataclass
class ServeConfig:
    mode: str = "a"
    n_pages: int = 8                      # RPR003 (never validated)

    def __post_init__(self):
        if self.mode != "a":
            raise ValueError(self.mode)


@dataclass
class EngineMetrics:
    n_steps: int = 0
    n_hidden: int = 0                     # RPR005 (not in summary)

    def summary(self):
        return {"n_steps": self.n_steps}


def runtime_path(xs):
    assert xs, "no tokens"                # RPR002
    import jax.numpy as jnp
    out = []
    for x in xs:
        out.append(jnp.asarray(x))        # RPR004 (scoped to core/)
    return out


def hot_step(params, tokens):
    import jax
    fn = jax.jit(lambda p, t: p + t)      # RPR006 (fresh cache per call)
    total = 0.0
    for t in tokens:
        total += t.item()                 # RPR007 (sync per iteration)
    return fn(params, tokens), total


def hot_step_inline(params, tokens):
    import jax
    return jax.jit(lambda p: p)(params)   # RPR006 (immediately invoked)


@dataclass
class SamplingParams:
    temperature: float = 0.0
    top_k: int = 0                        # RPR009 (never validated)

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(self.temperature)


@dataclass
class TenantTier:
    name: str = "gold"
    quota_tokens: int = 0                 # RPR009 (registry-loop misses it)

    def __post_init__(self):
        for knob in ("name",):
            if not getattr(self, knob):
                raise ValueError(knob)
'''

KERNEL_FIXTURE = '''\
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def launch_unchecked(x):                  # RPR008 (no contract raise)
    return pl.pallas_call(_kernel, out_shape=x)(x)


def launch_checked(x):
    if x.ndim != 2:
        raise ValueError(f"x must be 2D, got {x.shape}")
    return pl.pallas_call(_kernel, out_shape=x)(x)
'''


def _write_fixture(tmp_path):
    # under a repro/core/ directory so the core-scoped RPR004 rule applies
    d = tmp_path / "repro" / "core"
    d.mkdir(parents=True)
    f = d / "seeded.py"
    f.write_text(FIXTURE)
    return f


def _write_kernel_fixture(tmp_path):
    d = tmp_path / "repro" / "kernels"
    d.mkdir(parents=True)
    f = d / "seeded_kernel.py"
    f.write_text(KERNEL_FIXTURE)
    return f


def test_every_rule_fires_on_seeded_fixture(tmp_path):
    f = _write_fixture(tmp_path)
    kf = _write_kernel_fixture(tmp_path)
    findings = lint.lint_paths([str(f), str(kf)])
    assert {x.code for x in findings} == {
        "RPR001", "RPR002", "RPR003", "RPR004", "RPR005",
        "RPR006", "RPR007", "RPR008", "RPR009"}
    # both RPR009 target classes fire (self.<attr> and registry-loop
    # mention styles are each exercised without suppressing the finding)
    assert sum(1 for x in findings if x.code == "RPR009") == 2
    # both mutable-default shapes (arg literal + dataclass call) are hit
    assert sum(1 for x in findings if x.code == "RPR001") == 2
    # both jit-in-hot-path shapes (in-function + immediately-invoked)
    assert sum(1 for x in findings if x.code == "RPR006") == 2
    # the contract-checked launcher is NOT flagged
    rpr008 = [x for x in findings if x.code == "RPR008"]
    assert len(rpr008) == 1 and "launch_unchecked" in rpr008[0].message


def test_select_filters_rules(tmp_path):
    f = _write_fixture(tmp_path)
    findings = lint.lint_paths([str(f)], select=["RPR002"])
    assert findings and all(x.code == "RPR002" for x in findings)


def test_scope_suppresses_core_rule_outside_core(tmp_path):
    d = tmp_path / "repro" / "models"
    d.mkdir(parents=True)
    f = d / "seeded.py"
    f.write_text(FIXTURE)
    codes = {x.code for x in lint.lint_paths([str(f)])}
    assert "RPR004" not in codes          # jnp loops are legitimate there
    assert "RPR002" in codes              # unscoped rules still apply


def test_syntax_error_is_a_finding(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def f(:\n")
    findings = lint.lint_paths([str(f)])
    assert findings and findings[0].code == "RPR000"


def test_repo_src_is_clean():
    assert lint.lint_paths([str(SRC)]) == []


def test_repo_tests_and_benchmarks_are_clean():
    # CI lints these trees too (bare-assert excluded under tests/)
    root = SRC.parent
    assert lint.lint_paths([str(root / "tests"),
                            str(root / "benchmarks")]) == []


def test_bare_assert_excluded_in_tests(tmp_path):
    d = tmp_path / "tests"
    d.mkdir()
    f = d / "test_seeded.py"
    f.write_text("def test_x():\n    assert 1 + 1 == 2\n")
    assert lint.lint_paths([str(f)]) == []


def test_noqa_suppression(tmp_path):
    f = tmp_path / "seeded.py"
    f.write_text(
        "def a(xs):\n"
        "    assert xs  # rpr: noqa\n"              # blanket
        "def b(xs):\n"
        "    assert xs  # rpr: noqa[RPR002]\n"      # targeted, matches
        "def c(xs):\n"
        "    assert xs  # rpr: noqa[RPR001]\n"      # targeted, no match
        "def d(xs):\n"
        "    assert xs\n")                          # unsuppressed
    findings = lint.lint_paths([str(f)])
    assert [x.line for x in findings] == [6, 8]


def test_ignore_filters_rules(tmp_path):
    f = _write_fixture(tmp_path)
    findings = lint.lint_paths([str(f)], ignore=["RPR002", "jnp-in-loop"])
    codes = {x.code for x in findings}
    assert "RPR002" not in codes and "RPR004" not in codes
    assert "RPR001" in codes


def test_cli_exit_codes(tmp_path):
    f = _write_fixture(tmp_path)
    env_src = str(SRC)
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", env_src],
        capture_output=True, text=True, env={"PYTHONPATH": env_src})
    assert clean.returncode == 0, clean.stdout + clean.stderr
    seeded = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(f)],
        capture_output=True, text=True, env={"PYTHONPATH": env_src})
    assert seeded.returncode == 1
    assert "RPR001" in seeded.stdout


def test_cli_github_format(tmp_path):
    f = _write_fixture(tmp_path)
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(f),
         "--format", "github"],
        capture_output=True, text=True, env={"PYTHONPATH": str(SRC)})
    assert out.returncode == 1
    assert "::error file=" in out.stdout
    assert "title=RPR001" in out.stdout


def test_cli_ignore_flag(tmp_path):
    f = _write_fixture(tmp_path)
    codes = ",".join(r.code for r in lint.RULES)
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(f),
         "--ignore", codes],
        capture_output=True, text=True, env={"PYTHONPATH": str(SRC)})
    assert out.returncode == 0, out.stdout + out.stderr
