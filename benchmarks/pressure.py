"""Graceful degradation under KV pressure (beyond the paper's figures).

The paper's Figs. 5/14/15 show KV-cache usage climbing toward exhaustion
as batch size grows; this scenario pushes past it: an oversubscribed
page pool (~45% of the workload's total KV need) with generations that
far outgrow the pages reserved at admission.  The seed engine died with
``OutOfPages`` from the decode path here; the scheduler subsystem
(watermark admission + preemption by recomputation) completes every
request in all three modes, trading preemptions/latency for survival.
Each row also reruns the mode with ``preempt_policy="none"`` to document
the seed crash.
"""
import dataclasses

from benchmarks.common import make_requests, model_and_params
from repro.configs import ServeConfig
from repro.core.engine import Engine
from repro.core.kv_cache import OutOfPages

N_REQ, INPUT, OUTPUT = 6, 24, 48
MODES = ["sequential", "splitwiser", "splitwiser_mps"]


def _serve(mode):
    # per-request full need: (24+48)/8 = 9 pages; pool of 24 usable pages
    # holds < 3 of the 6 concurrent sequences
    return ServeConfig(mode=mode, max_batch=8, page_size=8, n_pages=25,
                       max_pages_per_seq=12, prefill_chunk=16, n_streams=2)


def int8_rows():
    """``pressure_kv_int8``: fp vs int8 KV pages at EQUAL pool bytes on
    the same oversubscribed workload.  ``kv_dtype="int8"`` shrinks a page
    to codes + a per-(token, head) f32 scale, so the byte-denominated
    pool holds >= 1.8x as many usable pages — under the identical page
    budget the scheduler preempts strictly less (usually not at all),
    int8 greedy streams stay bit-identical across all modes, and
    ``fp_agreement`` records the per-token fp-vs-int8 agreement (the
    quantization tolerance story; see EXPERIMENTS.md)."""
    model, params = model_and_params("opt-125m")
    vocab = model.cfg.vocab_size
    runs = []
    for mode in MODES:
        cells = {}
        for kv in ("fp", "int8"):
            eng = Engine(model, params,
                         dataclasses.replace(_serve(mode), kv_dtype=kv))
            reqs = make_requests(N_REQ, INPUT, OUTPUT, vocab)
            s = eng.run(reqs, max_steps=20_000).summary()
            cells[kv] = (s, eng.alloc.n_pages - 1,
                         [r.out_tokens for r in reqs])
        runs.append((mode, cells))
    ref_i8_toks = runs[0][1]["int8"][2]
    out = []
    for mode, cells in runs:
        (fp, fp_pages, fp_toks) = cells["fp"]
        (i8, i8_pages, i8_toks) = cells["int8"]
        agree = [t == u for ts, us in zip(fp_toks, i8_toks)
                 for t, u in zip(ts, us)]
        out.append(dict(
            bench="pressure_kv_int8", x=mode,
            n_requests=N_REQ,
            n_done=min(fp["n_done"], i8["n_done"]),
            all_complete=(fp["n_done"] == N_REQ == i8["n_done"]),
            usable_pages_fp=fp_pages, usable_pages_int8=i8_pages,
            page_ratio=round(i8_pages / fp_pages, 3),
            pool_bytes_fp=fp["kv_pool_bytes"],
            pool_bytes_int8=i8["kv_pool_bytes"],
            preemptions_fp=fp["n_preemptions"],
            preemptions_int8=i8["n_preemptions"],
            n_quant_pages=i8["n_quant_pages"],
            kv_peak_fp=round(fp["kv_usage_peak"], 4),
            kv_peak_int8=round(i8["kv_usage_peak"], 4),
            # int8 streams are bit-identical ACROSS MODES; vs fp they
            # agree only up to quantization (argmax can flip), reported
            # as a fraction rather than gated as equality
            tokens_match=i8_toks == ref_i8_toks,
            fp_agreement=round(sum(agree) / max(len(agree), 1), 4),
        ))
    return out


def rows():
    model, params = model_and_params("opt-125m")
    vocab = model.cfg.vocab_size
    out = []
    for mode in MODES:
        seed_cfg = dataclasses.replace(_serve(mode), preempt_policy="none",
                                       watermark=0.0, decode_reserve=0.0)
        seed_crash = False
        try:
            Engine(model, params, seed_cfg).run(
                make_requests(N_REQ, INPUT, OUTPUT, vocab), max_steps=20_000)
        except OutOfPages:
            seed_crash = True
        eng = Engine(model, params, _serve(mode))
        reqs = make_requests(N_REQ, INPUT, OUTPUT, vocab)
        s = eng.run(reqs, max_steps=20_000).summary()
        out.append(dict(
            bench="pressure_oversubscribed", x=mode,
            n_requests=N_REQ, n_done=s["n_done"],
            all_complete=all(len(r.out_tokens) == OUTPUT for r in reqs),
            seed_crash=seed_crash,
            n_preemptions=s["n_preemptions"],
            n_preempted_requests=s["n_preempted_requests"],
            throughput_tok_s=round(s["throughput_tok_s"], 1),
            kv_usage_peak=round(s["kv_usage_peak"], 4),
            e2e_p50=None if s["e2e"]["p50"] is None
                    else round(s["e2e"]["p50"], 4),
        ))
    return out
