"""Graceful degradation under KV pressure (beyond the paper's figures).

The paper's Figs. 5/14/15 show KV-cache usage climbing toward exhaustion
as batch size grows; this scenario pushes past it: an oversubscribed
page pool (~45% of the workload's total KV need) with generations that
far outgrow the pages reserved at admission.  The seed engine died with
``OutOfPages`` from the decode path here; the scheduler subsystem
(watermark admission + preemption by recomputation) completes every
request in all three modes, trading preemptions/latency for survival.
Each row also reruns the mode with ``preempt_policy="none"`` to document
the seed crash.
"""
import dataclasses

from benchmarks.common import make_requests, model_and_params
from repro.configs import ServeConfig
from repro.core.engine import Engine
from repro.core.kv_cache import OutOfPages

N_REQ, INPUT, OUTPUT = 6, 24, 48
MODES = ["sequential", "splitwiser", "splitwiser_mps"]


def _serve(mode):
    # per-request full need: (24+48)/8 = 9 pages; pool of 24 usable pages
    # holds < 3 of the 6 concurrent sequences
    return ServeConfig(mode=mode, max_batch=8, page_size=8, n_pages=25,
                       max_pages_per_seq=12, prefill_chunk=16, n_streams=2)


def rows():
    model, params = model_and_params("opt-125m")
    vocab = model.cfg.vocab_size
    out = []
    for mode in MODES:
        seed_cfg = dataclasses.replace(_serve(mode), preempt_policy="none",
                                       watermark=0.0, decode_reserve=0.0)
        seed_crash = False
        try:
            Engine(model, params, seed_cfg).run(
                make_requests(N_REQ, INPUT, OUTPUT, vocab), max_steps=20_000)
        except OutOfPages:
            seed_crash = True
        eng = Engine(model, params, _serve(mode))
        reqs = make_requests(N_REQ, INPUT, OUTPUT, vocab)
        s = eng.run(reqs, max_steps=20_000).summary()
        out.append(dict(
            bench="pressure_oversubscribed", x=mode,
            n_requests=N_REQ, n_done=s["n_done"],
            all_complete=all(len(r.out_tokens) == OUTPUT for r in reqs),
            seed_crash=seed_crash,
            n_preemptions=s["n_preemptions"],
            n_preempted_requests=s["n_preempted_requests"],
            throughput_tok_s=round(s["throughput_tok_s"], 1),
            kv_usage_peak=round(s["kv_usage_peak"], 4),
            e2e_p50=None if s["e2e"]["p50"] is None
                    else round(s["e2e"]["p50"], 4),
        ))
    return out
