"""Runtime-sanitizer overhead: wall cost of `ServeConfig.sanitize_level`.

The KV-state sanitizer (repro.analysis.invariants) re-validates the full
allocator/trie/scheduler contract after engine steps; this scenario
measures what that costs on the two serving profiles where its checks do
the most work — the shared-prefix workload (trie walks, refcounted
sharing, COW) and the oversubscribed-pressure workload (preemption,
reclaim, budget accounting) — at each level:

    off     baseline (no checker object at all)
    finish  full validation only after steps that finish a request
    soundness for CI-by-sampling; near-zero steady-state cost
    step    full validation after every step (CI tier-1 mode)
    call    step, plus per-mutator invariant subsets at every mutating
            PageAllocator/PrefixCache call (analysis/hooks.py) — the
            bug-attribution tier

Every arm also runs with the jit-dispatch sentinel enabled
(``ServeConfig.dispatch_sentinel``), so each row reports total compiles
and post-warmup recompiles per cell — the compiled-once guarantee is
measured on the same workloads that price the sanitizer.

Per (scenario, level): timed third run on a pre-compiled engine (two
warmup replays absorb jit compilation for both cold- and
warm-prefix-cache batch shapes, then ``mark_warm`` snapshots the compile
counts), microseconds per step, number of full-state validations and
call-site checks performed, and the overhead percentage vs the ``off``
arm.  A delta row per scenario asserts the greedy token streams are
bit-identical across levels — the sanitizer is read-only by contract,
and this is where that claim is continuously measured.  Numbers feed the
EXPERIMENTS.md recommendation (step in CI, call for bug hunts, finish
for local debugging, off in production).

    PYTHONPATH=src python -m benchmarks.sanitizer_overhead
"""
import dataclasses
import time

from benchmarks.common import make_requests, model_and_params
from benchmarks.pressure import INPUT, N_REQ, OUTPUT
from benchmarks.pressure import _serve as pressure_serve
from benchmarks.shared_prefix import OUTPUT as SP_OUTPUT
from benchmarks.shared_prefix import _requests as shared_requests
from benchmarks.shared_prefix import serve_cfg
from repro.core.engine import Engine

LEVELS = ("off", "finish", "step", "call")
MODE = "splitwiser_mps"
SP_N, SP_K = 8, 2


def _shared_cell(level):
    sc = serve_cfg(MODE, n_requests=SP_N, input_tokens=56,
                   output_tokens=SP_OUTPUT, max_batch=4, n_streams=2,
                   prefill_chunk=16)
    return dataclasses.replace(sc, enable_prefix_cache=True,
                               sanitize_level=level)


def _pressure_cell(level):
    return dataclasses.replace(pressure_serve(MODE), sanitize_level=level)


def _workload(scenario, vocab, rid_base):
    if scenario == "shared_prefix":
        reqs = shared_requests(SP_N, SP_K, vocab)
    else:
        reqs = make_requests(N_REQ, INPUT, OUTPUT, vocab)
    for i, r in enumerate(reqs):
        r.rid = rid_base + i
    return reqs


def rows():
    model, params = model_and_params("opt-125m")
    vocab = model.cfg.vocab_size
    out = []
    for scenario, cfg_fn in (("shared_prefix", _shared_cell),
                             ("pressure", _pressure_cell)):
        # throwaway cell: process-global one-time costs (XLA client init,
        # first-dispatch paths) must not land in the first timed arm
        warm = Engine(model, params, cfg_fn("off"))
        warm.run(_workload(scenario, vocab, 0), max_steps=40_000)
        warm.run(_workload(scenario, vocab, 1000), max_steps=40_000)
        base_us = None
        streams = {}
        for level in LEVELS:
            cfg = dataclasses.replace(cfg_fn(level), dispatch_sentinel=True)
            eng = Engine(model, params, cfg)
            # two warmup replays: the first compiles cold-cache shapes,
            # the second the warm-prefix-cache shapes the timed run sees
            eng.run(_workload(scenario, vocab, 0), max_steps=40_000)
            eng.run(_workload(scenario, vocab, 1000), max_steps=40_000)
            eng.dispatch.mark_warm()
            reqs = _workload(scenario, vocab, 2000)
            n0 = eng.metrics.n_steps
            t0 = time.perf_counter()
            eng.run(reqs, max_steps=40_000)
            wall = time.perf_counter() - t0
            n_steps = eng.metrics.n_steps - n0
            us_per_step = wall * 1e6 / max(n_steps, 1)
            if level == "off":
                base_us = us_per_step
            streams[level] = [r.out_tokens for r in reqs]
            san = eng.sanitizer
            out.append(dict(
                bench="sanitizer_overhead", x=f"{scenario}/{level}",
                n_requests=len(reqs),
                n_done=sum(1 for r in reqs if r.out_tokens),
                n_steps=n_steps,
                n_checks=0 if san is None else san.n_checks,
                n_call_checks=0 if san is None else san.n_call_checks,
                dispatch_compiles=eng.dispatch.total_compiles,
                dispatch_post_warm=sum(
                    eng.dispatch.post_warm_compiles().values()),
                wall_s=round(wall, 4),
                us_per_step=round(us_per_step, 1),
                overhead_pct=round(100.0 * (us_per_step - base_us) / base_us, 2),
            ))
        out.append(dict(
            bench="sanitizer_overhead_delta", x=scenario,
            tokens_match=all(streams[lv] == streams["off"] for lv in LEVELS),
        ))
    return out


def main():
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
