"""Paper Figs. 10-11 (vLLM experiments): SP vs MPx2 vs MPSx2.

  SP    — one continuous-batching engine (vLLM default).
  MPx2  — two engine replicas, each with HALF the resources, time-sliced
          on the device (the paper's multiprocessing-without-MPS arm; on a
          GPU the hardware scheduler context-switches them — here we
          interleave their steps, which is what time-slicing is).
  MPSx2 — both phases co-resident: our fused mixed-batching engine with
          the FULL resources (the single-program TPU realization of MPS).

Fig 10: total elapsed time to finish N requests (sweep N).
Fig 11: per-batch latency trade-off (time per engine step under MP).
"""
import time

import numpy as np

from benchmarks.common import (make_requests, model_and_params, serve_cfg)
from repro.core.engine import Engine


def _drain_time_sliced(engines):
    """Interleave engine steps until all drain (GPU time-slice analogue)."""
    t0 = time.perf_counter()
    while any(not e.idle() for e in engines):
        for e in engines:
            if not e.idle():
                e.step()
    return time.perf_counter() - t0


def rows(batches=(8, 16, 32)):
    model, params = model_and_params("opt-125m")
    V = model.cfg.vocab_size
    IN_TOK, OUT_TOK = 96, 12
    out = []
    for n in batches:
        # --- SP ---
        sc = serve_cfg("sequential", n_requests=n, input_tokens=IN_TOK,
                       output_tokens=OUT_TOK, max_batch=8)
        eng = Engine(model, params, sc)
        for r in make_requests(2, IN_TOK, 2, V):
            eng.submit(r)
        while not eng.idle():
            eng.step()                              # warm the jits
        eng = Engine(model, params, sc)
        t0 = time.perf_counter()
        m = eng.run(make_requests(n, IN_TOK, OUT_TOK, V))
        sp = time.perf_counter() - t0
        sp_step = sp / max(m.n_steps, 1)

        # --- MPx2: two replicas, half resources each, time-sliced ---
        sc2 = serve_cfg("sequential", n_requests=n // 2, input_tokens=IN_TOK,
                        output_tokens=OUT_TOK, max_batch=4)
        e1, e2 = Engine(model, params, sc2), Engine(model, params, sc2)
        reqs = make_requests(n, IN_TOK, OUT_TOK, V)
        for i, r in enumerate(reqs):
            (e1 if i % 2 == 0 else e2).submit(r)
        mp2 = _drain_time_sliced([e1, e2])
        mp2_steps = e1.metrics.n_steps + e2.metrics.n_steps
        mp2_step = mp2 / max(mp2_steps, 1)

        # --- MPSx2: fused mixed batching, full resources ---
        sc3 = serve_cfg("splitwiser_mps", n_requests=n, input_tokens=IN_TOK,
                        output_tokens=OUT_TOK, max_batch=8, n_streams=2,
                        prefill_chunk=32)
        eng3 = Engine(model, params, sc3)
        for r in make_requests(2, IN_TOK, 2, V):
            eng3.submit(r)
        while not eng3.idle():
            eng3.step()
        eng3 = Engine(model, params, sc3)
        t0 = time.perf_counter()
        m3 = eng3.run(make_requests(n, IN_TOK, OUT_TOK, V))
        mps = time.perf_counter() - t0

        out.append(dict(bench="fig10_elapsed", x=n, sp_s=round(sp, 3),
                        mp2_s=round(mp2, 3), mps2_s=round(mps, 3),
                        mps_speedup=round(sp / mps, 3),
                        mp2_speedup=round(sp / mp2, 3)))
        out.append(dict(bench="fig11_per_step", x=n,
                        sp_step_ms=round(sp_step * 1e3, 3),
                        mp2_step_ms=round(mp2_step * 1e3, 3)))
    return out
