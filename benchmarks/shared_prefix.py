"""Shared-prefix KV cache sweep (beyond the paper's figures).

The paper's Figs. 5/14/15 make the KV page pool the binding constraint;
this scenario measures how far the prefix cache stretches it: N requests
share K distinct system prompts (K swept from "everyone shares one
template" to "every prompt is unique"), each with a short unique tail.
Every (K, cache on/off) cell reports TTFT / throughput / peak KV usage /
prefill tokens actually computed / cache hit rate — the cache-off arm is
the PR-2 engine, the cache-on arm maps shared pages and prefills only
the uncached tail.

The **mid-page-divergence** scenario isolates cache *granularity*: every
prompt shares ``page_size - 1`` tokens and then diverges — full-page
caching (``prefix_cache_granularity="page"``) scores ~0 hits (no
complete page is ever shared), token-level caching ("token") COWs the
partially-matched page and reuses nearly the whole shared span.

    PYTHONPATH=src python -m benchmarks.shared_prefix [--smoke] [--mode M]
"""
import argparse
import dataclasses

import numpy as np

from benchmarks.common import model_and_params, serve_cfg
from repro.core.engine import Engine, Request
from repro.core.sampler import SamplingParams

N_REQ, SYS_TOKENS, TAIL_TOKENS, OUTPUT = 8, 48, 8, 8
K_SWEEP = (1, 2, 4, N_REQ)
MODE = "splitwiser_mps"


def _requests(n_req, k, vocab, seed=0):
    """n_req requests over k distinct system prompts + unique tails."""
    rng = np.random.RandomState(seed)
    systems = [list(rng.randint(2, vocab, size=SYS_TOKENS)) for _ in range(k)]
    return [
        Request(rid=i,
                prompt=systems[i % k] + list(rng.randint(2, vocab,
                                                         size=TAIL_TOKENS)),
                sampling=SamplingParams(max_new_tokens=OUTPUT))
        for i in range(n_req)
    ]


def _run(model, params, mode, k, cache, *, n_req=N_REQ, granularity="token"):
    sc = serve_cfg(mode, n_requests=n_req,
                   input_tokens=SYS_TOKENS + TAIL_TOKENS,
                   output_tokens=OUTPUT, max_batch=4, n_streams=2,
                   prefill_chunk=16)
    sc = dataclasses.replace(sc, enable_prefix_cache=cache,
                             prefix_cache_granularity=granularity)
    eng = Engine(model, params, sc)
    reqs = _requests(n_req, k, model.cfg.vocab_size)
    s = eng.run(reqs, max_steps=20_000).summary()
    return s, reqs


# --------------------------------------------- mid-page divergence arm ----
MID_PAGE, MID_TAIL, MID_N = 16, 9, 6   # prompts share MID_PAGE - 1 tokens:
                                       # divergence lands inside page one


def _midpage_requests(n_req, vocab, page_size, seed=3):
    """Prompts sharing ``page_size - 1`` tokens, then unique: no full page
    is ever common, so page-granular caching can't score a single hit."""
    rng = np.random.RandomState(seed)
    shared = list(rng.randint(2, vocab, size=page_size - 1))
    return [
        Request(rid=i,
                prompt=shared + list(rng.randint(2, vocab, size=MID_TAIL)),
                sampling=SamplingParams(max_new_tokens=OUTPUT))
        for i in range(n_req)
    ]


def midpage_rows(*, mode=MODE, n_req=MID_N):
    """``midpage_divergence`` cells (granularity page vs token) plus a
    ``midpage_delta`` summary row; greedy streams must match across arms."""
    model, params = model_and_params("opt-125m")
    out, cells, streams = [], {}, {}
    for gran in ("page", "token"):
        sc = serve_cfg(mode, n_requests=n_req,
                       input_tokens=MID_PAGE - 1 + MID_TAIL,
                       output_tokens=OUTPUT, max_batch=4, n_streams=2,
                       prefill_chunk=16, page_size=MID_PAGE)
        sc = dataclasses.replace(sc, enable_prefix_cache=True,
                                 prefix_cache_granularity=gran)
        eng = Engine(model, params, sc)
        reqs = _midpage_requests(n_req, model.cfg.vocab_size, sc.page_size)
        s = eng.run(reqs, max_steps=20_000).summary()
        cells[gran], streams[gran] = s, [r.out_tokens for r in reqs]
        out.append(dict(
            bench="midpage_divergence", x=f"{mode}/{gran}",
            n_requests=n_req, n_done=s["n_done"],
            all_complete=all(len(r.out_tokens) == OUTPUT for r in reqs),
            prefill_tokens=s["prefill_tokens_computed"],
            cached_tokens=s["cached_tokens"],
            hit_rate=round(s["cache_hit_rate"], 4),
            n_partial_hits=s["n_partial_hits"],
            n_cow=s["n_cow"],
        ))
    page, token = cells["page"], cells["token"]
    out.append(dict(
        bench="midpage_delta", x=mode,
        prefill_tokens_page=page["prefill_tokens_computed"],
        prefill_tokens_token=token["prefill_tokens_computed"],
        hit_rate_page=round(page["cache_hit_rate"], 4),
        hit_rate_token=round(token["cache_hit_rate"], 4),
        n_partial_hits=token["n_partial_hits"],
        tokens_match=streams["page"] == streams["token"],
    ))
    return out


# --------------------------------------------------- int8 KV tight pool ----
TIGHT_PAGES = 13   # usable pool (12 pages) holds 2 live requests (8) +
                   # barely 1 of the 4 distinct parked prefixes (3 each):
                   # the fp arm reclaim-thrashes templates, int8 (~3.4x
                   # pages at the same bytes) keeps all 4 resident
INT8_K, INT8_N_REQ = 4, 12


def int8_rows(*, mode=MODE, n_req=INT8_N_REQ):
    """``shared_prefix_int8``: prefix-cache hit capacity at EQUAL pool
    bytes.  n_req requests cycle over K=4 distinct system prompts on a
    pool sized so the fp arm must keep reclaiming parked templates to
    admit the next request — each template is evicted before its next
    user arrives, so hits collapse.  ``kv_dtype="int8"`` holds ~3x the
    pages in the same bytes: every template stays resident and the hit
    rate roughly doubles at identical byte cost.  A third cache-off
    int8 cell proves the quantized cache transparent: COW'd
    codes+scales must reproduce the uncached streams exactly."""
    model, params = model_and_params("opt-125m")
    out, cells = [], {}
    for kv, cache in (("fp", True), ("int8", True), ("int8", False)):
        sc = serve_cfg(mode, n_requests=n_req,
                       input_tokens=SYS_TOKENS + TAIL_TOKENS,
                       output_tokens=OUTPUT, max_batch=2, n_streams=2,
                       prefill_chunk=16)
        sc = dataclasses.replace(sc, enable_prefix_cache=cache,
                                 n_pages=TIGHT_PAGES, kv_dtype=kv)
        eng = Engine(model, params, sc)
        reqs = _requests(n_req, INT8_K, model.cfg.vocab_size)
        s = eng.run(reqs, max_steps=20_000).summary()
        cells[(kv, cache)] = (s, eng.alloc.n_pages - 1,
                              [r.out_tokens for r in reqs])
        if cache:
            out.append(dict(
                bench="shared_prefix_int8", x=f"{mode}/{kv}",
                n_requests=n_req, n_done=s["n_done"],
                all_complete=all(len(r.out_tokens) == OUTPUT for r in reqs),
                usable_pages=eng.alloc.n_pages - 1,
                cached_tokens=s["cached_tokens"],
                hit_rate=round(s["cache_hit_rate"], 4),
                n_reclaims=s["n_reclaims"],
                n_preemptions=s["n_preemptions"],
            ))
    (fp, fp_pages, _) = cells[("fp", True)]
    (i8, i8_pages, i8_toks) = cells[("int8", True)]
    out.append(dict(
        bench="shared_prefix_int8_delta", x=mode,
        cached_tokens_fp=fp["cached_tokens"],
        cached_tokens_int8=i8["cached_tokens"],
        page_ratio=round(i8_pages / fp_pages, 3),
        hit_rate_fp=round(fp["cache_hit_rate"], 4),
        hit_rate_int8=round(i8["cache_hit_rate"], 4),
        # quantized cache transparency: cache-on int8 streams must equal
        # the cache-off int8 streams bit-for-bit (COW'd codes + scales)
        tokens_match=i8_toks == cells[("int8", False)][2],
    ))
    return out


def rows(*, n_req=N_REQ, k_sweep=K_SWEEP, mode=MODE):
    model, params = model_and_params("opt-125m")
    # warm the compile caches outside the measured cells
    _run(model, params, mode, 1, True, n_req=2)
    out = []
    for k in k_sweep:
        cells = {}
        for cache in (False, True):
            s, reqs = _run(model, params, mode, k, cache, n_req=n_req)
            cells[cache] = s
            out.append(dict(
                bench="shared_prefix",
                x=f"{mode}/K={k}/{'cache' if cache else 'nocache'}",
                n_requests=n_req, n_done=s["n_done"],
                all_complete=all(len(r.out_tokens) == OUTPUT for r in reqs),
                prefill_tokens=s["prefill_tokens_computed"],
                cached_tokens=s["cached_tokens"],
                hit_rate=round(s["cache_hit_rate"], 4),
                pages_shared_peak=s["pages_shared_peak"],
                n_reclaims=s["n_reclaims"],
                kv_usage_peak=round(s["kv_usage_peak"], 4),
                throughput_tok_s=round(s["throughput_tok_s"], 1),
                ttft_mean=None if s["ttft"]["mean"] is None
                          else round(s["ttft"]["mean"], 5),
            ))
        on, off = cells[True], cells[False]
        out.append(dict(
            bench="shared_prefix_delta", x=f"{mode}/K={k}",
            prefill_tokens_saved=(off["prefill_tokens_computed"]
                                  - on["prefill_tokens_computed"]),
            kv_peak_off=round(off["kv_usage_peak"], 4),
            kv_peak_on=round(on["kv_usage_peak"], 4),
            hit_rate_on=round(on["cache_hit_rate"], 4),
            tokens_match=None,   # cross-arm equality asserted by tests
        ))
    out.extend(midpage_rows(mode=mode))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny K=1 cell per arm (CI gate)")
    ap.add_argument("--mode", default=MODE)
    args = ap.parse_args()
    if args.smoke:
        model, params = model_and_params("opt-125m")
        res = {}
        for cache in (False, True):
            s, reqs = _run(model, params, args.mode, 1, cache, n_req=4)
            res[cache] = (s, [r.out_tokens for r in reqs])
        on, off = res[True][0], res[False][0]
        if res[True][1] != res[False][1]:
            raise RuntimeError("greedy outputs diverge with prefix cache on")
        if on["cache_hit_rate"] <= 0:
            raise RuntimeError("no cache hits on K=1 workload")
        if on["prefill_tokens_computed"] >= off["prefill_tokens_computed"]:
            raise RuntimeError(
                "prefix cache did not reduce prefill tokens computed")
        delta = [r for r in midpage_rows(mode=args.mode)
                 if r["bench"] == "midpage_delta"][0]
        if not delta["tokens_match"]:
            raise RuntimeError(
                "greedy outputs diverge across cache granularities")
        if delta["prefill_tokens_token"] >= delta["prefill_tokens_page"]:
            raise RuntimeError(
                "token-level caching did not beat full-page on "
                "mid-page divergence")
        if delta["hit_rate_page"] != 0 or delta["n_partial_hits"] <= 0:
            raise RuntimeError(
                "mid-page scenario regressed: expected zero full-page hits "
                f"(got {delta['hit_rate_page']}) and some partial hits "
                f"(got {delta['n_partial_hits']})")
        print(f"smoke ok: hit_rate={on['cache_hit_rate']:.3f} "
              f"prefill {off['prefill_tokens_computed']}"
              f"->{on['prefill_tokens_computed']} "
              f"kv_peak {off['kv_usage_peak']:.3f}->{on['kv_usage_peak']:.3f} "
              f"midpage prefill {delta['prefill_tokens_page']}"
              f"->{delta['prefill_tokens_token']} "
              f"(partial_hits={delta['n_partial_hits']})")
        return
    for r in rows(mode=args.mode):
        print(r)


if __name__ == "__main__":
    main()
