"""Shared-prefix KV cache sweep (beyond the paper's figures).

The paper's Figs. 5/14/15 make the KV page pool the binding constraint;
this scenario measures how far the prefix cache stretches it: N requests
share K distinct system prompts (K swept from "everyone shares one
template" to "every prompt is unique"), each with a short unique tail.
Every (K, cache on/off) cell reports TTFT / throughput / peak KV usage /
prefill tokens actually computed / cache hit rate — the cache-off arm is
the PR-2 engine, the cache-on arm maps shared pages and prefills only
the uncached tail.

The **mid-page-divergence** scenario isolates cache *granularity*: every
prompt shares ``page_size - 1`` tokens and then diverges — full-page
caching (``prefix_cache_granularity="page"``) scores ~0 hits (no
complete page is ever shared), token-level caching ("token") COWs the
partially-matched page and reuses nearly the whole shared span.

    PYTHONPATH=src python -m benchmarks.shared_prefix [--smoke] [--mode M]
"""
import argparse
import dataclasses

import numpy as np

from benchmarks.common import model_and_params, serve_cfg
from repro.core.engine import Engine, Request
from repro.core.sampler import SamplingParams

N_REQ, SYS_TOKENS, TAIL_TOKENS, OUTPUT = 8, 48, 8, 8
K_SWEEP = (1, 2, 4, N_REQ)
MODE = "splitwiser_mps"


def _requests(n_req, k, vocab, seed=0):
    """n_req requests over k distinct system prompts + unique tails."""
    rng = np.random.RandomState(seed)
    systems = [list(rng.randint(2, vocab, size=SYS_TOKENS)) for _ in range(k)]
    return [
        Request(rid=i,
                prompt=systems[i % k] + list(rng.randint(2, vocab,
                                                         size=TAIL_TOKENS)),
                sampling=SamplingParams(max_new_tokens=OUTPUT))
        for i in range(n_req)
    ]


def _run(model, params, mode, k, cache, *, n_req=N_REQ, granularity="token"):
    sc = serve_cfg(mode, n_requests=n_req,
                   input_tokens=SYS_TOKENS + TAIL_TOKENS,
                   output_tokens=OUTPUT, max_batch=4, n_streams=2,
                   prefill_chunk=16)
    sc = dataclasses.replace(sc, enable_prefix_cache=cache,
                             prefix_cache_granularity=granularity)
    eng = Engine(model, params, sc)
    reqs = _requests(n_req, k, model.cfg.vocab_size)
    s = eng.run(reqs, max_steps=20_000).summary()
    return s, reqs


# --------------------------------------------- mid-page divergence arm ----
MID_PAGE, MID_TAIL, MID_N = 16, 9, 6   # prompts share MID_PAGE - 1 tokens:
                                       # divergence lands inside page one


def _midpage_requests(n_req, vocab, page_size, seed=3):
    """Prompts sharing ``page_size - 1`` tokens, then unique: no full page
    is ever common, so page-granular caching can't score a single hit."""
    rng = np.random.RandomState(seed)
    shared = list(rng.randint(2, vocab, size=page_size - 1))
    return [
        Request(rid=i,
                prompt=shared + list(rng.randint(2, vocab, size=MID_TAIL)),
                sampling=SamplingParams(max_new_tokens=OUTPUT))
        for i in range(n_req)
    ]


def midpage_rows(*, mode=MODE, n_req=MID_N):
    """``midpage_divergence`` cells (granularity page vs token) plus a
    ``midpage_delta`` summary row; greedy streams must match across arms."""
    model, params = model_and_params("opt-125m")
    out, cells, streams = [], {}, {}
    for gran in ("page", "token"):
        sc = serve_cfg(mode, n_requests=n_req,
                       input_tokens=MID_PAGE - 1 + MID_TAIL,
                       output_tokens=OUTPUT, max_batch=4, n_streams=2,
                       prefill_chunk=16, page_size=MID_PAGE)
        sc = dataclasses.replace(sc, enable_prefix_cache=True,
                                 prefix_cache_granularity=gran)
        eng = Engine(model, params, sc)
        reqs = _midpage_requests(n_req, model.cfg.vocab_size, sc.page_size)
        s = eng.run(reqs, max_steps=20_000).summary()
        cells[gran], streams[gran] = s, [r.out_tokens for r in reqs]
        out.append(dict(
            bench="midpage_divergence", x=f"{mode}/{gran}",
            n_requests=n_req, n_done=s["n_done"],
            all_complete=all(len(r.out_tokens) == OUTPUT for r in reqs),
            prefill_tokens=s["prefill_tokens_computed"],
            cached_tokens=s["cached_tokens"],
            hit_rate=round(s["cache_hit_rate"], 4),
            n_partial_hits=s["n_partial_hits"],
            n_cow=s["n_cow"],
        ))
    page, token = cells["page"], cells["token"]
    out.append(dict(
        bench="midpage_delta", x=mode,
        prefill_tokens_page=page["prefill_tokens_computed"],
        prefill_tokens_token=token["prefill_tokens_computed"],
        hit_rate_page=round(page["cache_hit_rate"], 4),
        hit_rate_token=round(token["cache_hit_rate"], 4),
        n_partial_hits=token["n_partial_hits"],
        tokens_match=streams["page"] == streams["token"],
    ))
    return out


def rows(*, n_req=N_REQ, k_sweep=K_SWEEP, mode=MODE):
    model, params = model_and_params("opt-125m")
    # warm the compile caches outside the measured cells
    _run(model, params, mode, 1, True, n_req=2)
    out = []
    for k in k_sweep:
        cells = {}
        for cache in (False, True):
            s, reqs = _run(model, params, mode, k, cache, n_req=n_req)
            cells[cache] = s
            out.append(dict(
                bench="shared_prefix",
                x=f"{mode}/K={k}/{'cache' if cache else 'nocache'}",
                n_requests=n_req, n_done=s["n_done"],
                all_complete=all(len(r.out_tokens) == OUTPUT for r in reqs),
                prefill_tokens=s["prefill_tokens_computed"],
                cached_tokens=s["cached_tokens"],
                hit_rate=round(s["cache_hit_rate"], 4),
                pages_shared_peak=s["pages_shared_peak"],
                n_reclaims=s["n_reclaims"],
                kv_usage_peak=round(s["kv_usage_peak"], 4),
                throughput_tok_s=round(s["throughput_tok_s"], 1),
                ttft_mean=None if s["ttft"]["mean"] is None
                          else round(s["ttft"]["mean"], 5),
            ))
        on, off = cells[True], cells[False]
        out.append(dict(
            bench="shared_prefix_delta", x=f"{mode}/K={k}",
            prefill_tokens_saved=(off["prefill_tokens_computed"]
                                  - on["prefill_tokens_computed"]),
            kv_peak_off=round(off["kv_usage_peak"], 4),
            kv_peak_on=round(on["kv_usage_peak"], 4),
            hit_rate_on=round(on["cache_hit_rate"], 4),
            tokens_match=None,   # cross-arm equality asserted by tests
        ))
    out.extend(midpage_rows(mode=mode))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny K=1 cell per arm (CI gate)")
    ap.add_argument("--mode", default=MODE)
    args = ap.parse_args()
    if args.smoke:
        model, params = model_and_params("opt-125m")
        res = {}
        for cache in (False, True):
            s, reqs = _run(model, params, args.mode, 1, cache, n_req=4)
            res[cache] = (s, [r.out_tokens for r in reqs])
        on, off = res[True][0], res[False][0]
        if res[True][1] != res[False][1]:
            raise RuntimeError("greedy outputs diverge with prefix cache on")
        if on["cache_hit_rate"] <= 0:
            raise RuntimeError("no cache hits on K=1 workload")
        if on["prefill_tokens_computed"] >= off["prefill_tokens_computed"]:
            raise RuntimeError(
                "prefix cache did not reduce prefill tokens computed")
        delta = [r for r in midpage_rows(mode=args.mode)
                 if r["bench"] == "midpage_delta"][0]
        if not delta["tokens_match"]:
            raise RuntimeError(
                "greedy outputs diverge across cache granularities")
        if delta["prefill_tokens_token"] >= delta["prefill_tokens_page"]:
            raise RuntimeError(
                "token-level caching did not beat full-page on "
                "mid-page divergence")
        if delta["hit_rate_page"] != 0 or delta["n_partial_hits"] <= 0:
            raise RuntimeError(
                "mid-page scenario regressed: expected zero full-page hits "
                f"(got {delta['hit_rate_page']}) and some partial hits "
                f"(got {delta['n_partial_hits']})")
        print(f"smoke ok: hit_rate={on['cache_hit_rate']:.3f} "
              f"prefill {off['prefill_tokens_computed']}"
              f"->{on['prefill_tokens_computed']} "
              f"kv_peak {off['kv_usage_peak']:.3f}->{on['kv_usage_peak']:.3f} "
              f"midpage prefill {delta['prefill_tokens_page']}"
              f"->{delta['prefill_tokens_token']} "
              f"(partial_hits={delta['n_partial_hits']})")
        return
    for r in rows(mode=args.mode):
        print(r)


if __name__ == "__main__":
    main()
