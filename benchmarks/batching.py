"""Paper Figs. 12-13 (appendix): time per output token vs batch size."""
import time

from benchmarks.common import make_requests, model_and_params, serve_cfg
from repro.core.engine import Engine


def rows():
    model, params = model_and_params("opt-125m")
    V = model.cfg.vocab_size
    out = []
    for bs in [1, 2, 4, 8]:
        sc = serve_cfg("sequential", n_requests=bs, input_tokens=48,
                       output_tokens=16, max_batch=bs)
        eng = Engine(model, params, sc)
        m0 = eng.run(make_requests(bs, 48, 4, V))          # warm
        eng = Engine(model, params, sc)
        m = eng.run(make_requests(bs, 48, 16, V))
        s = m.summary()
        decode_steps = sum(1 for k in m.step_kinds if k == "decode")
        gen = sum(r.n_generated for r in m.requests.values())
        out.append(dict(bench="fig12_time_per_token", x=bs,
                        tbt_mean_ms=round((s["tbt"]["mean"] or 0) * 1e3, 3),
                        tok_per_decode_step=round(gen / max(decode_steps, 1), 2),
                        throughput=round(s["throughput_tok_s"], 1)))
    return out
