"""Paper Figs. 6-9 (HF pipeline experiments): sequential vs Splitwiser vs
Splitwiser+MPS, wall-clock on CPU with the reduced opt-125m.

  Fig 6: total elapsed time, sequential vs splitwiser
  Fig 7: steady-state throughput, 4 parallel streams vs sequential
  Fig 8: E2E latency scaling #parallel streams (1/2/4/8)
  Fig 9: + MPS arm (fused mixed batching)
Paper claims to validate directionally: splitwiser+MPS < sequential E2E;
throughput(4 streams) >= 1.1x sequential (§IV-B).
"""
from benchmarks.common import run_workload

N_REQ = 12
IN_TOK = 96
OUT_TOK = 12


def rows():
    out = []
    base, _ = run_workload("opt-125m", "sequential", n_requests=N_REQ,
                           input_tokens=IN_TOK, output_tokens=OUT_TOK,
                           max_batch=4)
    out.append(dict(bench="fig6_e2e", x="sequential",
                    wall_s=round(base["wall_s"], 3),
                    throughput=round(base["throughput_tok_s"], 1),
                    ttft_mean=round(base["ttft"]["mean"], 4)))
    for streams in [1, 2, 4, 8]:
        s, _ = run_workload("opt-125m", "splitwiser_mps", n_requests=N_REQ,
                            input_tokens=IN_TOK, output_tokens=OUT_TOK,
                            max_batch=4, n_streams=streams, prefill_chunk=32)
        out.append(dict(bench="fig8_scaling_streams", x=streams,
                        wall_s=round(s["wall_s"], 3),
                        throughput=round(s["throughput_tok_s"], 1),
                        speedup_vs_seq=round(base["wall_s"] / s["wall_s"], 3)))
        if streams == 4:
            out.append(dict(
                bench="fig7_throughput_4proc", x="splitwiser4_vs_seq",
                ratio=round(s["throughput_tok_s"] / base["throughput_tok_s"], 3)))
    sw, _ = run_workload("opt-125m", "splitwiser", n_requests=N_REQ,
                         input_tokens=IN_TOK, output_tokens=OUT_TOK,
                         max_batch=4, n_streams=2, prefill_chunk=32)
    mps, _ = run_workload("opt-125m", "splitwiser_mps", n_requests=N_REQ,
                          input_tokens=IN_TOK, output_tokens=OUT_TOK,
                          max_batch=4, n_streams=2, prefill_chunk=32)
    out.append(dict(bench="fig9_mps_arms", x="splitwiser(noMPS)",
                    wall_s=round(sw["wall_s"], 3),
                    reduction_vs_seq=round(1 - sw["wall_s"] / base["wall_s"], 3)))
    out.append(dict(bench="fig9_mps_arms", x="splitwiser+MPS(fused)",
                    wall_s=round(mps["wall_s"], 3),
                    reduction_vs_seq=round(1 - mps["wall_s"] / base["wall_s"], 3)))
    return out
