"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig10] [--json PATH]

Prints ``name,us_per_call,derived`` CSV rows plus a validation block that
checks the paper's headline claims directionally (see EXPERIMENTS.md).
``--json PATH`` additionally writes the rows and check results as
machine-readable JSON (per-scenario throughput/TTFT/TBT/cache stats) so
perf trajectories can be recorded as ``BENCH_*.json``.
"""
import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings of suite names to run")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced deterministic sizing for suites that "
                         "support it (CI regression-gate runs)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows + validation results as JSON")
    args, _ = ap.parse_known_args()

    from benchmarks import batching, kv_usage, mixed_longprompt, open_loop
    from benchmarks import phase_intensity, policy_sweep, pressure
    from benchmarks import sanitizer_overhead, shared_prefix, slo_tenants
    from benchmarks import splitwiser_hf, splitwiser_vllm

    # (name, rows_fn, accepts_smoke)
    suites = [
        ("phase_intensity", phase_intensity.rows, False),   # Figs 2-4
        ("kv_usage", kv_usage.rows, False),                 # Figs 5, 14, 15
        ("splitwiser_hf", splitwiser_hf.rows, False),       # Figs 6-9
        ("splitwiser_vllm", splitwiser_vllm.rows, False),   # Figs 10-11
        ("batching", batching.rows, False),                 # Figs 12-13
        ("pressure", pressure.rows, False),                 # beyond-paper: KV pressure
        ("pressure_int8", pressure.int8_rows, False),       # beyond-paper: int8 KV pages
        ("open_loop", open_loop.rows, True),                # beyond-paper: Poisson arrivals
        ("mixed_longprompt", mixed_longprompt.rows, True),  # beyond-paper: chunked tail TBT
        ("shared_prefix", shared_prefix.rows, False),       # beyond-paper: prefix cache
        ("shared_prefix_int8", shared_prefix.int8_rows, False),  # int8 hit capacity
        ("policy_sweep", policy_sweep.rows, True),          # beyond-paper: policy matrix
        ("sanitizer_overhead", sanitizer_overhead.rows, False),  # analysis layer cost
        ("slo_tenants", slo_tenants.rows, True),            # beyond-paper: SLO deadlines
    ]
    only = args.only.split(",") if args.only else None
    all_rows = []
    print("name,us_per_call,derived")
    for name, fn, accepts_smoke in suites:
        if only and not any(tok in name for tok in only):
            continue
        t0 = time.perf_counter()
        rows = fn(smoke=True) if (args.smoke and accepts_smoke) else fn()
        dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
        for r in rows:
            all_rows.append(r)
            derived = {k: v for k, v in r.items() if k not in ("bench", "x")}
            print(f"{r['bench']}[{r['x']}],{dt_us:.0f},"
                  f"\"{json.dumps(derived, default=str)}\"")

    checks = []
    # ---- validation vs the paper's claims (directional) ----
    # checks are keyed on the rows actually collected, so partial runs
    # (--only) still validate — and record in --json — whatever they ran
    if all_rows:

        def by(b):
            return [r for r in all_rows if r["bench"] == b]
        pf = by("fig2_prefill_intensity")
        dc = by("fig3_decode_intensity")
        if pf and dc:
            checks.append(("prefill arithmetic intensity grows with input "
                           "tokens",
                           pf[-1]["arith_intensity"] > pf[0]["arith_intensity"]))
            checks.append(("prefill is compute-bound at 2048 input tokens",
                           pf[-1]["compute_bound"]))
            checks.append(("decode stays bandwidth-bound at every context len",
                           all(not r["compute_bound"] for r in dc)))
        kv = by("fig5_kv_usage_vs_batch")
        if kv:
            checks.append(("KV usage increases with batch size",
                           kv[-1]["token_usage"] > kv[0]["token_usage"]))
        f7 = by("fig7_throughput_4proc")
        if f7:
            checks.append(("throughput(4 streams) >= 1.1x sequential (paper: 1.1x)",
                           f7[0]["ratio"] >= 1.1))
        f9 = by("fig9_mps_arms")
        if f9:
            mps = [r for r in f9 if "fused" in str(r["x"])][0]
            checks.append(("splitwiser+MPS reduces E2E vs sequential (paper: 18.2%)",
                           mps["reduction_vs_seq"] > 0))
            nomps = [r for r in f9 if "noMPS" in str(r["x"])][0]
            checks.append(("MPS arm beats the time-sliced (no-MPS) arm "
                           "(paper Fig 9: splitwiser alone shows no gain on A10)",
                           mps["reduction_vs_seq"] > nomps["reduction_vs_seq"]))
        pr = by("pressure_oversubscribed")
        if pr:
            checks.append(("oversubscribed pool crashes the seed admission "
                           "policy (OutOfPages) in every mode",
                           all(r["seed_crash"] for r in pr)))
            checks.append(("scheduler completes every request under KV "
                           "pressure in every mode",
                           all(r["n_done"] == r["n_requests"]
                               and r["all_complete"] for r in pr)))
            checks.append(("survival is preemption-driven (evictions occurred)",
                           all(r["n_preemptions"] > 0 for r in pr)))
        pi = by("pressure_kv_int8")
        if pi:
            checks.append(("int8 KV pages at equal pool bytes buy >= 1.8x "
                           "usable pages in every mode",
                           all(r["page_ratio"] >= 1.8
                               and r["pool_bytes_int8"] <= r["pool_bytes_fp"]
                               for r in pi)))
            checks.append(("int8 KV strictly reduces preemptions on the "
                           "oversubscribed pool in every mode",
                           all(r["preemptions_int8"] < r["preemptions_fp"]
                               for r in pi)))
            checks.append(("int8 greedy streams bit-identical across all "
                           "serving modes under KV pressure, all requests "
                           "complete",
                           all(r["tokens_match"] and r["all_complete"]
                               for r in pi)))
        si = by("shared_prefix_int8_delta")
        if si:
            checks.append(("int8 pages raise prefix-cache hit capacity at "
                           "equal pool bytes on the tight pool",
                           all(r["cached_tokens_int8"] > r["cached_tokens_fp"]
                               and r["hit_rate_int8"] > r["hit_rate_fp"]
                               for r in si)))
            checks.append(("quantized prefix cache is transparent: int8 "
                           "cache-on streams bit-identical to cache-off",
                           all(r["tokens_match"] for r in si)))
        ol = by("open_loop_poisson")
        if ol:
            checks.append(("open-loop Poisson run finishes every request",
                           all(r["n_done"] == r["n_requests"] for r in ol)))
            checks.append(("every first token lands at/after its request's "
                           "arrival (timed admission)",
                           all(r["respects_arrivals"] for r in ol)))
        od = by("open_loop_det")
        if od:
            checks.append(("deterministic open-loop arm finishes every "
                           "request with timed admission honored",
                           all(r["n_done"] == r["n_requests"]
                               and r["all_complete"]
                               and r["respects_arrivals"] for r in od)))
            checks.append(("serving hot path stays compiled-once: zero "
                           "post-warmup recompiles on the served workload",
                           all(r["dispatch_post_warm"] == 0 for r in od)))
        ml = by("mixed_longprompt_det")
        if ml:
            checks.append(("mixed long-prompt arm finishes every request "
                           "with timed admission honored",
                           all(r["n_done"] == r["n_requests"]
                               and r["all_complete"]
                               and r["respects_arrivals"] for r in ml)))
            checks.append(("greedy streams bit-identical across serving "
                           "modes on the mixed long-prompt workload",
                           all(r["tokens_match"] for r in ml)))
            by_mode = {r["x"]: r for r in ml}
            if {"sequential", "splitwiser", "chunked"} <= by_mode.keys():
                ch, seq, sw = (by_mode["chunked"], by_mode["sequential"],
                               by_mode["splitwiser"])
                checks.append(("chunked prefill bounds the tail: p99 TBT "
                               "strictly below both monolithic modes at "
                               "equal completed tokens",
                               ch["tbt_vp99"] < seq["tbt_vp99"]
                               and ch["tbt_vp99"] < sw["tbt_vp99"]
                               and ch["completed_tokens"]
                               == seq["completed_tokens"]
                               == sw["completed_tokens"]))
                checks.append(("chunked serving stays compiled-once on the "
                               "mixed workload (zero post-warm recompiles)",
                               ch["dispatch_post_warm"] == 0))
        sp = by("shared_prefix_delta")
        if sp:
            k1 = [r for r in sp if "K=1" in str(r["x"])][0]
            kun = sp[-1]    # K == N: every prompt unique
            checks.append(("prefix cache skips prefill work when every "
                           "request shares one system prompt (K=1)",
                           k1["prefill_tokens_saved"] > 0
                           and k1["hit_rate_on"] > 0))
            checks.append(("shared pages lower peak KV usage at K=1",
                           k1["kv_peak_on"] < k1["kv_peak_off"]))
            checks.append(("cache benefit shrinks as prompts diversify "
                           "(K=1 saves more than K=N)",
                           k1["prefill_tokens_saved"]
                           >= kun["prefill_tokens_saved"]))
        mp = by("midpage_delta")
        if mp:
            checks.append(("mid-page divergence: token-level caching "
                           "strictly beats full-page on prefill tokens "
                           "computed",
                           all(r["prefill_tokens_token"]
                               < r["prefill_tokens_page"] for r in mp)))
            checks.append(("mid-page divergence: full-page caching scores "
                           "zero hits, token-level reuses the shared span "
                           "via partial-page COW",
                           all(r["hit_rate_page"] == 0
                               and r["hit_rate_token"] > 0
                               and r["n_partial_hits"] > 0 for r in mp)))
            checks.append(("greedy streams bit-identical across cache "
                           "granularities",
                           all(r["tokens_match"] for r in mp)))
        f10 = by("fig10_elapsed")
        if f10:
            big = f10[-1]
            checks.append(("MPSx2 speedup at largest batch (paper: 1.42x)",
                           big["mps_speedup"] > 1.0))
            checks.append(("MPx2 (time-sliced halves) does NOT beat MPS "
                           "(paper: MPx2 < SP < MPSx2)",
                           big["mp2_speedup"] <= big["mps_speedup"]))
        pw = by("policy_sweep_delta")
        if pw:
            checks.append(("cache_aware admission strictly raises hit rate "
                           "over fcfs on the Zipf-skewed workload (twins no "
                           "longer double-miss) for every eviction x preempt",
                           all(r["hit_rate_cache_aware"] > r["hit_rate_fcfs"]
                               for r in pw)))
            checks.append(("greedy token streams bit-identical across the "
                           "whole policy matrix",
                           all(r["tokens_match"] for r in pw)))
            checks.append(("every policy combination completes every request "
                           "under page pressure with reclaims",
                           all(r["n_done"] == r["n_requests"]
                               and r["n_reclaims"] > 0
                               for r in by("policy_sweep"))))
        sd = by("slo_tenants_det")
        if sd:
            checks.append(("multi-tenant SLO arms finish every request "
                           "with timed admission honored",
                           all(r["n_done"] == r["n_requests"]
                               and r["respects_arrivals"] for r in sd)))
            checks.append(("deadline scheduling stays compiled-once on the "
                           "tenant workload (zero post-warm recompiles)",
                           all(r["dispatch_post_warm"] == 0 for r in sd)))
            checks.append(("per-tenant token quota engaged on the burst "
                           "tenant under deadline admission",
                           all(r["quota_holds"] > 0 for r in sd
                               if "deadline" in str(r["x"]))))
        sdd = by("slo_tenants_delta")
        if sdd:
            checks.append(("deadline admission+preemption strictly raises "
                           "SLO attainment over fcfs+latest at equal load",
                           all(r["attainment_improved"]
                               and r["attainment_deadline"]
                               > r["attainment_fcfs"] for r in sdd)))
            checks.append(("deadline scheduling strictly lowers the gold "
                           "tenant's p99 TTFT (the burst victim)",
                           all(r["victim_p99_improved"]
                               and r["gold_p99_deadline"]
                               < r["gold_p99_fcfs"] for r in sdd)))
        sid = by("slo_tenants_identity")
        if sid:
            checks.append(("deadline policies are ordering-only: greedy "
                           "streams bit-identical to the fcfs oracle in "
                           "every mode when no deadline binds",
                           all(r["tokens_match"] and r["all_complete"]
                               for r in sid)))
        so = by("sanitizer_overhead_delta")
        if so:
            checks.append(("sanitizer is read-only: greedy token streams "
                           "bit-identical across off/finish/step/call",
                           all(r["tokens_match"] for r in so)))
        soh = by("sanitizer_overhead")
        if soh:
            checks.append(("dispatch sentinel sees zero post-warmup "
                           "recompiles at every sanitize level",
                           all(r["dispatch_post_warm"] == 0 for r in soh)))
    if checks:
        print("\n== paper-claim validation ==")
    ok = True
    for msg, passed in checks:
        print(f"[{'PASS' if passed else 'FAIL'}] {msg}")
        ok &= bool(passed)
    if args.json:
        with open(args.json, "w") as f:
            # ok is null for a partial run (--only): its checks are
            # recorded individually (the regression gate compares them),
            # but the run must not be machine-readable as "ALL claims
            # passed" when most suites never executed
            json.dump({"rows": all_rows,
                       "checks": [{"msg": m, "passed": bool(p)}
                                  for m, p in checks],
                       "ok": bool(ok) if not args.only else None},
                      f, indent=1, default=str)
        print(f"wrote {args.json}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
