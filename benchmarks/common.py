"""Shared benchmark plumbing."""
import time

import jax
import numpy as np

from repro.configs import ServeConfig, get_config
from repro.core.engine import Engine, Request
from repro.core.sampler import SamplingParams
from repro.data import report_tokens
from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model


def reduced_model(arch="opt-125m"):
    cfg = get_config(arch).reduced()
    return Model(arch, cfg, FAMILY_MODULE[cfg.family], CACHE_KIND[cfg.family])


_PARAMS_CACHE = {}


def model_and_params(arch="opt-125m"):
    if arch not in _PARAMS_CACHE:
        m = reduced_model(arch)
        _PARAMS_CACHE[arch] = (m, m.init(jax.random.PRNGKey(0)))
    return _PARAMS_CACHE[arch]


def make_requests(n, input_tokens, output_tokens, vocab, seed=0, *,
                  sampling=None, arrivals=None):
    """Synthetic requests; `sampling` overrides the default greedy
    SamplingParams, `arrivals` (seconds offsets) marks them for open-loop
    replay."""
    prompts = report_tokens(n, input_tokens, vocab, seed)
    sp = sampling if sampling is not None else \
        SamplingParams(max_new_tokens=output_tokens)
    return [Request(rid=i, prompt=list(p), sampling=sp,
                    arrival=None if arrivals is None else float(arrivals[i]))
            for i, p in enumerate(prompts)]


def serve_cfg(mode, *, n_requests, input_tokens, output_tokens, max_batch=8,
              n_streams=2, prefill_chunk=32, page_size=16):
    per_seq = (input_tokens + output_tokens) // page_size + 2
    return ServeConfig(
        mode=mode, max_batch=max_batch, page_size=page_size,
        n_pages=max(256, (n_requests + 2) * per_seq + 8),
        max_pages_per_seq=per_seq, prefill_chunk=prefill_chunk,
        n_streams=n_streams)


def run_workload(arch, mode, *, n_requests=8, input_tokens=64,
                 output_tokens=16, warm=True, **kw):
    model, params = model_and_params(arch)
    sc = serve_cfg(mode, n_requests=n_requests, input_tokens=input_tokens,
                   output_tokens=output_tokens, **kw)
    if warm:  # compile outside the timed region
        eng = Engine(model, params, sc)
        eng.run(make_requests(2, input_tokens, 2, model.cfg.vocab_size), 200)
    eng = Engine(model, params, sc)
    reqs = make_requests(n_requests, input_tokens, output_tokens,
                         model.cfg.vocab_size)
    m = eng.run(reqs, max_steps=100_000)
    return m.summary(), eng
