"""Multi-tenant SLO serving at scale: deadline scheduling vs FCFS.

The paper's phase split exists so latency-sensitive traffic survives on
constrained hardware, and PR policies so far optimize cache hits and
occupancy — this scenario measures what none of them could: *per-tenant
deadline attainment under contention*.  An open-loop workload drives a
Zipf-skewed interactive tenant mix (``gold`` with tight TTFT/TBT
targets, ``silver`` with looser ones) through periodic *diurnal bursts*
from a deadline-free ``batch`` tenant whose long prompts head-of-line
block everything behind them under FCFS.  The same workload runs twice
at equal load — ``fcfs``+``latest`` vs ``deadline``+``deadline``
(slack-ranked admission, per-tenant token quotas, max-slack preemption,
weight-aware chunk carving) — and reports per-tenant virtual-clock
p50/p99 TTFT and worst-gap TBT plus SLO-attainment fractions.

Everything runs on the counting clock (each ``now()`` reading advances a
fixed tick), so every percentile is a pure function of the scheduling
trace: deterministic on any runner, baseline-gated in CI
(``regression_gate.py``), with the jit-dispatch sentinel asserting the
measured runs stay compiled-once.

Arms:

* ``slo_tenants_det`` — the fcfs/deadline pair on ``mode="chunked"``
  (the planner's weight-aware carve is live there); per-tenant
  percentile + attainment rows, both baseline-gated;
* ``slo_tenants_delta`` — the head-to-head: attainment must strictly
  rise and the gold tenant's p99 TTFT strictly fall under ``deadline``
  at equal load (booleans gated against flips);
* ``slo_tenants_identity`` — deadline policies active + quota'd tiers
  but **no deadline anywhere**: greedy streams must be bit-identical to
  the fcfs oracle in all four engine modes (policies change *when*,
  never *what*).

Smoke mode (``--smoke``, what CI's bench gate runs) scales the same
shape down to a few hundred requests; the full run drives thousands.
"""
import dataclasses

import numpy as np

from benchmarks.common import model_and_params, serve_cfg
from repro.configs.base import TenantTier
from repro.core.engine import Engine, Request
from repro.core.sampler import SamplingParams
from repro.core.slo import SLOParams

# virtual seconds between interactive arrivals: ~10 clock readings, a
# fraction of one request's service time, so queues actually form and
# admission order is load-bearing
DET_GAP = 0.001

INT_INPUT, INT_OUTPUT = 32, 8        # interactive request shape
BATCH_INPUT, BATCH_OUTPUT = 128, 4   # burst request shape (long prompts)

# tenant tiers: targets are virtual seconds on the counting clock
# (tick = 1e-4 per reading).  gold's TTFT budget sits between the two
# arms' tails — under deadline scheduling its p95 TTFT lands below it,
# under FCFS a burst's head-of-line block pushes >10% of gold past it.
GOLD_TTFT, GOLD_TBT = 0.0015, 0.004
SILVER_TTFT = 0.0015
TIERS = (
    TenantTier("gold", ttft_target=GOLD_TTFT, tbt_target=GOLD_TBT,
               weight=4.0),
    TenantTier("silver", ttft_target=SILVER_TTFT, weight=2.0),
    # deadline-free bulk tenant: its quota is what keeps a burst from
    # monopolizing the engine (~2 burst requests in flight at once)
    TenantTier("batch", quota_tokens=2 * (BATCH_INPUT + BATCH_OUTPUT) + 8),
)


class _CountingClock:
    """Deterministic time source: each reading advances one fixed tick
    (same idiom as the ``open_loop`` deterministic arm)."""

    def __init__(self, tick: float = 1e-4):
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t


def _vp(vals, q):
    vals = [v for v in vals if v is not None]
    return None if not vals else round(float(np.percentile(vals, q)), 4)


def _workload(V, n_interactive, n_bursts, burst_size, rid_base=0):
    """Zipf-skewed interactive tenants + periodic batch bursts.

    Interactive requests arrive every ``DET_GAP`` virtual seconds with
    tenants drawn Zipf-style (gold dominates — the skew that makes one
    tenant's tail the number operators actually watch).  Every
    ``n_interactive // n_bursts`` arrivals, ``burst_size`` long-prompt
    batch requests land *at the same instant* (the diurnal peak): under
    FCFS they head-of-line block the interactive queue; under
    ``deadline`` they rank last (infinite slack) and queue behind the
    batch tenant's token quota.
    """
    rng = np.random.default_rng(7)
    # Zipf over the interactive tenants: p(rank r) ~ 1/r^1.5
    ranks = np.array([1.0, 2.0]) ** -1.5
    p_gold = ranks[0] / ranks.sum()
    reqs = []
    rid = rid_base
    period = max(n_interactive // max(n_bursts, 1), 1)
    for i in range(n_interactive):
        t = i * DET_GAP
        tenant = "gold" if rng.random() < p_gold else "silver"
        prompt = list(rng.integers(2, V, size=INT_INPUT))
        reqs.append(Request(
            rid=rid, prompt=prompt, arrival=t,
            sampling=SamplingParams(max_new_tokens=INT_OUTPUT),
            slo=SLOParams(tenant=tenant)))
        rid += 1
        if n_bursts and i % period == period // 2:
            for _ in range(burst_size):
                reqs.append(Request(
                    rid=rid,
                    prompt=list(rng.integers(2, V, size=BATCH_INPUT)),
                    arrival=t,
                    sampling=SamplingParams(max_new_tokens=BATCH_OUTPUT),
                    slo=SLOParams(tenant="batch")))
                rid += 1
    return reqs


def _serve(mode, n_requests, admission, preempt):
    base = serve_cfg(mode, n_requests=max(n_requests // 3, 8),
                     input_tokens=BATCH_INPUT, output_tokens=INT_OUTPUT,
                     max_batch=8, page_size=16)
    return dataclasses.replace(
        base, admission_policy=admission, preempt_policy=preempt,
        tenants=TIERS, dispatch_sentinel=True)


def _run_arm(model, params, V, mode, admission, preempt, sizes):
    n_interactive, n_bursts, burst_size = sizes
    sc = _serve(mode, n_interactive + n_bursts * burst_size,
                admission, preempt)
    eng = Engine(model, params, sc, time_fn=_CountingClock())
    # two warmup replays on the same engine (open_loop idiom): first
    # compiles the cold shapes, second the steady-state ones — only then
    # is "compiled once" checkable on the measured run
    for base in (1_000_000, 2_000_000):
        warm = _workload(V, max(n_interactive // 4, 8), 1, burst_size,
                         rid_base=base)
        eng.run(warm, open_loop=True, max_steps=400_000)
    eng.poll()
    eng.dispatch.mark_warm()
    reqs = _workload(V, n_interactive, n_bursts, burst_size)
    events = list(eng.stream(reqs, open_loop=True, max_steps=2_000_000))
    outputs = eng.poll()
    firsts = {e.rid: e.t for e in events if e.first}
    measured = {r.rid for r in reqs}

    def tenant_vals(tenant, fn):
        return [fn(m) for rid, m in eng.metrics.requests.items()
                if rid in measured and m.tenant == tenant
                and m.t_done is not None]
    # summary() covers warmup rids too; recompute attainment/percentiles
    # over the measured run only
    def attainment(*tenants):
        oks = [ok for t in tenants
               for ok in tenant_vals(t, lambda m: m.slo_ok)
               if ok is not None]
        return round(sum(oks) / len(oks), 4) if oks else None
    row = dict(
        bench="slo_tenants_det", x=f"{mode}@{admission}+{preempt}",
        n_requests=len(reqs),
        n_done=sum(1 for o in outputs if o.rid in measured),
        respects_arrivals=all(firsts[o.rid] >= o.arrival
                              for o in outputs if o.rid in measured),
        slo_attainment=attainment("gold", "silver"),
        gold_attainment=attainment("gold"),
        silver_attainment=attainment("silver"),
        gold_ttft_vp50=_vp(tenant_vals("gold", lambda m: m.ttft), 50),
        gold_ttft_vp99=_vp(tenant_vals("gold", lambda m: m.ttft), 99),
        gold_tbtmax_vp99=_vp(tenant_vals("gold", lambda m: m.tbt_max), 99),
        silver_ttft_vp99=_vp(tenant_vals("silver", lambda m: m.ttft), 99),
        batch_ttft_vp50=_vp(tenant_vals("batch", lambda m: m.ttft), 50),
        n_preempted=sum(o.n_preempted for o in outputs if o.rid in measured),
        dispatch_post_warm=sum(eng.dispatch.post_warm_compiles().values()),
    )
    if admission == "deadline":
        row["quota_holds"] = int(
            eng.metrics.policy_counters.get("quota_holds", 0))
    return row


def _det_rows(model, params, V, smoke):
    # smoke: ~200 requests (CI bench gate); full: thousands
    sizes = (160, 4, 8) if smoke else (1600, 16, 24)
    rows, arms = [], {}
    for admission, preempt in (("fcfs", "latest"), ("deadline", "deadline")):
        row = _run_arm(model, params, V, "chunked",
                       admission, preempt, sizes)
        rows.append(row)
        arms[admission] = row
    f, d = arms["fcfs"], arms["deadline"]
    rows.append(dict(
        bench="slo_tenants_delta", x="chunked",
        attainment_fcfs=f["slo_attainment"],
        attainment_deadline=d["slo_attainment"],
        gold_p99_fcfs=f["gold_ttft_vp99"],
        gold_p99_deadline=d["gold_ttft_vp99"],
        attainment_improved=d["slo_attainment"] > f["slo_attainment"],
        victim_p99_improved=d["gold_ttft_vp99"] < f["gold_ttft_vp99"],
    ))
    return rows


def _identity_rows(model, params, V):
    """Deadline policies + quota'd tiers, zero deadlines: greedy streams
    must match the fcfs sequential oracle bit-for-bit in all 4 modes."""
    tiers = (TenantTier("batch", quota_tokens=96),)
    rng = np.random.default_rng(3)
    def reqs():
        out = []
        for i in range(10):
            out.append(Request(
                rid=i, prompt=list(rng.integers(2, V, size=24)),
                sampling=SamplingParams(max_new_tokens=6),
                slo=SLOParams(tenant="batch" if i % 3 == 0 else "default")))
        return out
    rng_state = rng.bit_generator.state
    # pool tight enough that admission backpressure engages (the arm
    # proves ordering-only behaviour, so streams must survive pressure)
    base = dataclasses.replace(
        serve_cfg("sequential", n_requests=6, input_tokens=24,
                  output_tokens=6, max_batch=3, page_size=4),
        n_pages=20, max_pages_per_seq=10)
    oracle_reqs = reqs()
    Engine(model, params, base).run(oracle_reqs, max_steps=100_000)
    oracle = [r.out_tokens for r in oracle_reqs]
    rows = []
    for mode in ("sequential", "splitwiser", "splitwiser_mps", "chunked"):
        rng.bit_generator.state = rng_state
        sc = dataclasses.replace(base, mode=mode,
                                 admission_policy="deadline",
                                 preempt_policy="deadline", tenants=tiers)
        eng = Engine(model, params, sc)
        rs = reqs()
        s = eng.run(rs, max_steps=100_000).summary()
        rows.append(dict(
            bench="slo_tenants_identity", x=mode,
            n_requests=len(rs), n_done=s["n_done"],
            all_complete=s["n_done"] == len(rs),
            tokens_match=[r.out_tokens for r in rs] == oracle,
            n_preemptions=s["n_preemptions"],
        ))
    return rows


def rows(smoke: bool = False):
    model, params = model_and_params("opt-125m")
    V = model.cfg.vocab_size
    return (_det_rows(model, params, V, smoke)
            + _identity_rows(model, params, V))
