"""Scheduling-policy sweep: admission x eviction x preemption.

Splitwiser's constrained-resource premise makes the scheduler's three
decisions — who is admitted, which cached pages are reclaimed, who is
preempted — the dominant lever on throughput/TTFT once the shared-prefix
cache is in place.  This scenario sweeps the full policy matrix
(``admission {fcfs, cache_aware} x eviction {lru, fifo, cost} x preempt
{latest, cache_aware}``, cache on) over a mixed multi-tenant workload:
N requests over K system-prompt templates with Zipf-skewed popularity
(a few hot tenants, a long tail) plus unique per-request tails, against
a page pool deliberately too small for the total demand — so admission
ordering, reclaimable-page stripping, and victim choice all fire.

Per cell: cache hit rate, prefill tokens computed, throughput, TTFT,
preemptions, reclaims, and the policy counters (admission holds/reorders,
cost evictions, cheap preemptions).  Greedy token streams must be
bit-identical across every combination — policies change *when* work
happens, never *what* is computed.

    PYTHONPATH=src python -m benchmarks.policy_sweep [--smoke] [--mode M]
"""
import argparse
import itertools

import numpy as np

from benchmarks.common import model_and_params
from repro.configs import ServeConfig
from repro.core.engine import Engine, Request
from repro.core.sampler import SamplingParams

N_REQ, SYS_TOKENS, TAIL_TOKENS, OUTPUT = 12, 32, 8, 12
N_TEMPLATES, ZIPF_A = 4, 1.5
MODE = "splitwiser_mps"

ADMISSIONS = ("fcfs", "cache_aware")
EVICTIONS = ("lru", "fifo", "cost")
PREEMPTS = ("latest", "cache_aware")


def _requests(vocab, n_req=N_REQ, k=N_TEMPLATES, seed=0):
    """Zipf-skewed, bursty multi-tenant arrivals: each burst draws a
    tenant (system-prompt template) with p(rank) ~ 1/rank^a and fires 2-3
    back-to-back queries sharing that template, each with a unique tail —
    the same-batch-identical-prefix case where FCFS admission double-
    misses and cache-aware admission holds the twins one round."""
    rng = np.random.RandomState(seed)
    templates = [list(rng.randint(2, vocab, size=SYS_TOKENS))
                 for _ in range(k)]
    p = 1.0 / np.arange(1, k + 1) ** ZIPF_A
    p /= p.sum()
    reqs = []
    while len(reqs) < n_req:
        t = rng.choice(k, p=p)
        for _ in range(min(int(rng.randint(2, 4)), n_req - len(reqs))):
            reqs.append(Request(
                rid=len(reqs),
                prompt=templates[t]
                + list(rng.randint(2, vocab, size=TAIL_TOKENS)),
                sampling=SamplingParams(max_new_tokens=OUTPUT)))
    return reqs


def _serve(mode, admission, eviction, preempt, *, n_pages=24):
    """A pool far below the workload's total page demand (12 requests x
    ~7 pages against 23 usable): reclaimable-page stripping — and, on the
    colder-cache arms, preemption — must fire for the run to complete."""
    return ServeConfig(
        mode=mode, max_batch=4, page_size=8, n_pages=n_pages,
        max_pages_per_seq=10, prefill_chunk=8, n_streams=2,
        enable_prefix_cache=True, admission_policy=admission,
        eviction_policy=eviction, preempt_policy=preempt)


def _run(model, params, serve, *, n_req=N_REQ, seed=0):
    eng = Engine(model, params, serve)
    reqs = _requests(model.cfg.vocab_size, n_req=n_req, seed=seed)
    s = eng.run(reqs, max_steps=40_000).summary()
    return s, [r.out_tokens for r in reqs]


def rows(*, mode=MODE, smoke=False):
    # smoke: the CI-gate subset — every admission policy, but only the
    # eviction/preempt arms that exercise distinct code paths (lru vs the
    # cost model; latest preemption).  Rows stay deterministic and
    # bit-identical to the same cells of the full matrix.
    evictions = ("lru", "cost") if smoke else EVICTIONS
    preempts = ("latest",) if smoke else PREEMPTS
    model, params = model_and_params("opt-125m")
    _run(model, params, _serve(mode, "fcfs", "lru", "latest"), n_req=2)  # warm
    out, streams, cells = [], {}, {}
    for adm, ev, pre in itertools.product(ADMISSIONS, evictions, preempts):
        s, toks = _run(model, params, _serve(mode, adm, ev, pre))
        streams[(adm, ev, pre)] = toks
        cells[(adm, ev, pre)] = s
        pc = s["policy_counters"]
        out.append(dict(
            bench="policy_sweep", x=f"{mode}/{adm}+{ev}+{pre}",
            n_requests=N_REQ, n_done=s["n_done"],
            hit_rate=round(s["cache_hit_rate"], 4),
            prefill_tokens=s["prefill_tokens_computed"],
            cached_tokens=s["cached_tokens"],
            n_preemptions=s["n_preemptions"],
            n_reclaims=s["n_reclaims"],
            kv_usage_peak=round(s["kv_usage_peak"], 4),
            throughput_tok_s=round(s["throughput_tok_s"], 1),
            ttft_mean=None if s["ttft"]["mean"] is None
                      else round(s["ttft"]["mean"], 5),
            admission_holds=pc.get("admission_holds", 0),
            admission_reorders=pc.get("admission_reorders", 0),
            cost_evictions=pc.get("cost_evictions", 0),
            cheap_preemptions=pc.get("cheap_preemptions", 0),
        ))
    first = next(iter(streams.values()))
    identical = all(t == first for t in streams.values())
    for ev, pre in itertools.product(evictions, preempts):
        fcfs = cells[("fcfs", ev, pre)]
        aware = cells[("cache_aware", ev, pre)]
        out.append(dict(
            bench="policy_sweep_delta", x=f"{mode}/{ev}+{pre}",
            hit_rate_fcfs=round(fcfs["cache_hit_rate"], 4),
            hit_rate_cache_aware=round(aware["cache_hit_rate"], 4),
            prefill_tokens_saved=(fcfs["prefill_tokens_computed"]
                                  - aware["prefill_tokens_computed"]),
            tokens_match=identical,
        ))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fcfs-vs-cache_aware admission only (CI gate)")
    ap.add_argument("--mode", default=MODE)
    args = ap.parse_args()
    if args.smoke:
        model, params = model_and_params("opt-125m")
        res = {}
        for adm in ADMISSIONS:
            serve = _serve(args.mode, adm, "lru", "latest", n_pages=64)
            res[adm] = _run(model, params, serve, n_req=8)
        (s_f, t_f), (s_a, t_a) = res["fcfs"], res["cache_aware"]
        if t_a != t_f:
            raise RuntimeError(
                "greedy outputs diverge across admission policies")
        if s_a["cache_hit_rate"] <= s_f["cache_hit_rate"]:
            raise RuntimeError(
                "cache_aware admission did not raise the hit rate: "
                f"{s_a['cache_hit_rate']} vs fcfs {s_f['cache_hit_rate']}")
        if s_a["policy_counters"].get("admission_holds", 0) <= 0:
            raise RuntimeError("cache_aware admission never held a twin")
        print(f"smoke ok: hit_rate fcfs={s_f['cache_hit_rate']:.3f} -> "
              f"cache_aware={s_a['cache_hit_rate']:.3f}, "
              f"holds={s_a['policy_counters']['admission_holds']}")
        return
    for r in rows(mode=args.mode):
        print(r)


if __name__ == "__main__":
    main()
