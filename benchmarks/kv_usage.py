"""Paper Figs. 5, 14, 15: KV-cache usage (%) vs batch size and
input/output token lengths — measured from our paged allocator exactly the
way vLLM reported it in the paper."""
import numpy as np

from benchmarks.common import make_requests, model_and_params, serve_cfg
from repro.core.engine import Engine


def rows():
    model, params = model_and_params("opt-125m")
    out = []
    # Fig 5: usage vs batch size, both phases
    for bs in [1, 2, 4, 8]:
        sc = serve_cfg("sequential", n_requests=bs, input_tokens=48,
                       output_tokens=8, max_batch=bs)
        eng = Engine(model, params, sc)
        m = eng.run(make_requests(bs, 48, 8, model.cfg.vocab_size))
        prefill_usage = [u for u, k in zip(m.kv_usage_trace, m.step_kinds,
                                           strict=True) if k == "prefill"]
        decode_usage = [u for u, k in zip(m.kv_usage_trace, m.step_kinds,
                                          strict=True) if k == "decode"]
        out.append(dict(bench="fig5_kv_usage_vs_batch", x=bs,
                        prefill_usage=round(max(prefill_usage, default=0), 4),
                        token_usage=round(max(decode_usage, default=0), 4)))
    # Fig 14/15: usage matrix over (input len, max output len)
    for inp in [32, 64, 128]:
        for outp in [8, 16, 32]:
            sc = serve_cfg("sequential", n_requests=4, input_tokens=inp,
                           output_tokens=outp, max_batch=4)
            eng = Engine(model, params, sc)
            m = eng.run(make_requests(4, inp, outp, model.cfg.vocab_size))
            out.append(dict(bench="fig15_kv_usage_matrix", x=f"{inp}x{outp}",
                            peak_usage=round(max(m.kv_usage_trace), 4)))
    return out
