"""Mixed long-prompt open-loop scenario: the tail-TBT cliff, gated.

A chat-style decode stream (short prompts, steady token emission) is
interrupted mid-flight by 2k-token prompts.  This is the workload the
chunked-prefill subsystem exists for: monolithic prefill (sequential —
and, phase-exclusively, splitwiser) stalls every in-flight decode for
the whole prompt, so the chat stream's p99 inter-token gap explodes;
``mode="chunked"`` carves the prompt into ``chunk_tokens``-budget
chunks with the decodes riding in every round, bounding the gap by the
budget.

The arm is deterministic: a *work-proportional* virtual clock advances
after each engine step by the number of tokens the step computed
(prefill chunk + decode batch) plus one scheduling tick.  Unlike the
open-loop counting clock (one tick per reading), inter-token gaps then
model compute *cost* — a monolithic 2k-token prefill stalls in-flight
decodes for ~2k ticks, a chunked one for ~``chunk_tokens`` — so the
tail-TBT bound is a pure function of the scheduling trace and CI gates
the p95/p99 percentiles exactly (``regression_gate.py``), plus
zero-post-warm-recompile via the jit-dispatch sentinel.  Token streams
must be bit-identical across the three modes at equal completed tokens:
chunking changes *when* prompt tokens are prefilled, never *what* is
generated.
"""
import dataclasses
from collections import deque

import numpy as np

from benchmarks.common import make_requests, model_and_params, serve_cfg
from repro.core.engine import Engine

N_CHAT, CHAT_IN, CHAT_OUT = 4, 16, 24
N_LONG, LONG_OUT = 2, 2
CHUNK_TOKENS = 48                   # < one splitwiser prefill round
                                    # (n_streams * prefill_chunk + decodes)
MODES = ["sequential", "splitwiser", "chunked"]
# virtual-tick arrivals: chat at t=0, the long prompts landing while the
# chat streams are mid-decode (see the timeline note in _requests)
LONG_ARRIVALS = (100.0, 140.0)


class _WorkClock:
    """Deterministic work-proportional time source (see module docstring);
    the drive loop advances it explicitly, readings never tick."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, d: float) -> None:
        self.t += float(d)

    def advance_to(self, t: float) -> None:
        self.t = max(self.t, float(t))


def _vp(vals, q):
    vals = [v for v in vals if v is not None]
    return None if not vals else round(float(np.percentile(vals, q)), 4)


def _requests(vocab, long_in):
    """4 chat requests at t=0 plus 2 long prompts arriving mid-stream.

    Timeline sanity (work ticks): chat prefill costs ~CHAT_IN*N_CHAT
    ticks, then each decode round costs ~1+N_CHAT — so the chat streams
    emit from roughly t=70 to t>=185 in every mode, and arrivals at 100
    and 140 land squarely inside the decode stream."""
    chat = make_requests(N_CHAT, CHAT_IN, CHAT_OUT, vocab, seed=1,
                         arrivals=[0.0] * N_CHAT)
    longs = make_requests(N_LONG, long_in, LONG_OUT, vocab, seed=2,
                          arrivals=LONG_ARRIVALS)
    for i, r in enumerate(longs):
        r.rid = N_CHAT + i
    return chat + longs


def _drive(eng, reqs, clock, max_steps=200_000):
    """Open-loop feed on the work clock (the Engine.stream loop, with the
    clock advanced per step by the tokens that step computed).  Warmup
    replays and the measured run share this loop so the measured run
    sees only shapes the warmups already compiled."""
    t0 = clock.t
    pending = deque(sorted(reqs, key=lambda r: (r.arrival or 0.0, r.rid)))
    events = []
    steps = 0
    while (pending or not eng.idle()) and steps < max_steps:
        while pending and t0 + pending[0].arrival <= clock.t:
            r = pending.popleft()
            r.arrival = t0 + r.arrival
            eng.submit(r)
        if pending and eng.idle():
            clock.advance_to(t0 + pending[0].arrival)
            continue
        pf0 = eng.metrics.n_prefill_tokens
        evs = eng.step()
        events.extend(evs)
        clock.advance(1 + (eng.metrics.n_prefill_tokens - pf0) + len(evs))
        steps += 1
    return events


def _row(model, params, vocab, mode, long_in):
    n_req = N_CHAT + N_LONG
    sc = dataclasses.replace(
        serve_cfg(mode, n_requests=n_req, input_tokens=long_in,
                  output_tokens=CHAT_OUT, max_batch=8),
        dispatch_sentinel=True)
    if mode == "chunked":
        sc = dataclasses.replace(sc, chunk_tokens=CHUNK_TOKENS)
    clock = _WorkClock()
    eng = Engine(model, params, sc, time_fn=clock)
    # two warmup replays on the same engine (cold shapes, then any
    # second-pass shapes) before arming the compiled-once check
    for base in (1000, 2000):
        warm = _requests(vocab, long_in)
        for r in warm:
            r.rid += base
        _drive(eng, warm, clock)
    eng.poll()
    eng.dispatch.mark_warm()
    reqs = _requests(vocab, long_in)
    events = _drive(eng, reqs, clock)
    outputs = eng.poll()
    firsts = {e.rid: e.t for e in events if e.first}
    gaps = []     # pooled inter-token gaps: the chat streams' TBT tail
    for o in outputs:
        gaps += [b - a for a, b in zip(o.token_times, o.token_times[1:])]
    row = dict(
        bench="mixed_longprompt_det", x=mode,
        n_requests=n_req, n_done=len(outputs),
        all_complete=all(o.finish_reason == "length" for o in outputs),
        respects_arrivals=all(
            firsts[o.rid] >= o.arrival for o in outputs),
        completed_tokens=sum(len(o.tokens) for o in outputs),
        long_input_tokens=long_in,
        tbt_vp50=_vp(gaps, 50), tbt_vp95=_vp(gaps, 95),
        tbt_vp99=_vp(gaps, 99),
        n_preempted=sum(o.n_preempted for o in outputs),
        dispatch_post_warm=sum(eng.dispatch.post_warm_compiles().values()),
        streams={o.rid: list(o.tokens) for o in outputs},
    )
    if mode == "chunked":
        s = eng.metrics.summary()
        row["n_chunks"] = s["n_chunks"]
        row["chunk_occupancy"] = s["chunk_occupancy"]
    return row


def rows(smoke: bool = False):
    model, params = model_and_params("opt-125m")
    vocab = model.cfg.vocab_size
    long_in = 512 if smoke else 2048
    out = [_row(model, params, vocab, mode, long_in) for mode in MODES]
    ref = next(r for r in out if r["x"] == "sequential")["streams"]
    for r in out:
        r["tokens_match"] = r.pop("streams") == ref
    return out


if __name__ == "__main__":
    import json
    import sys
    for r in rows(smoke="--smoke" in sys.argv):
        print(json.dumps(r, default=str))
