"""Open-loop serving: Poisson arrivals against the virtual clock.

The paper frames Splitwiser as a serving system fed by queues of
requests (§V), but every closed-loop figure replays a batch all at once.
This scenario feeds requests in at Poisson arrival times
(``Engine.run(..., open_loop=True)``) and reports TTFT/TBT measured from
the streamed ``RequestOutput``s — the latency a client would actually
see at a given offered load, per engine mode.

Arrival times live on the engine's virtual clock (idle gaps are
fast-forwarded), so the scenario is deterministic in shape and runs at
full speed regardless of the offered rate.
"""
import numpy as np

from benchmarks.common import make_requests, model_and_params, serve_cfg
from repro.core.engine import Engine

N_REQ, INPUT, OUTPUT = 10, 48, 12
RATES = (5.0, 50.0)          # offered load, requests per virtual second
MODES = ["sequential", "splitwiser_mps"]


def _agg(vals):
    vals = [v for v in vals if v is not None]
    if not vals:
        return None, None
    return (round(float(np.mean(vals)), 4),
            round(float(np.median(vals)), 4))


def rows():
    model, params = model_and_params("opt-125m")
    V = model.cfg.vocab_size
    out = []
    for mode in MODES:
        sc = serve_cfg(mode, n_requests=N_REQ, input_tokens=INPUT,
                       output_tokens=OUTPUT, max_batch=8)
        Engine(model, params, sc).run(       # compile outside the timed runs
            make_requests(2, INPUT, 2, V), max_steps=200)
        for rate in RATES:
            rng = np.random.default_rng(0)
            arrivals = np.cumsum(rng.exponential(1.0 / rate, size=N_REQ))
            eng = Engine(model, params, sc)
            reqs = make_requests(N_REQ, INPUT, OUTPUT, V, arrivals=arrivals)
            events = list(eng.stream(reqs, open_loop=True, max_steps=100_000))
            outputs = eng.poll()
            by_rid = {o.rid: o for o in outputs}
            firsts = {e.rid: e.t for e in events if e.first}
            ttft_mean, ttft_p50 = _agg([o.ttft for o in outputs])
            tbt_mean, _ = _agg([o.tbt for o in outputs])
            out.append(dict(
                bench="open_loop_poisson", x=f"{mode}@{rate:g}rps",
                n_requests=N_REQ, n_done=len(outputs),
                all_finished_by_length=all(
                    o.finish_reason == "length" for o in outputs),
                respects_arrivals=all(
                    firsts[o.rid] >= o.arrival for o in outputs),
                arrival_span_s=round(float(arrivals[-1]), 3),
                ttft_mean=ttft_mean, ttft_p50=ttft_p50, tbt_mean=tbt_mean,
                n_preempted=sum(by_rid[r].n_preempted for r in by_rid),
            ))
    return out
