"""Open-loop serving: Poisson arrivals against the virtual clock.

The paper frames Splitwiser as a serving system fed by queues of
requests (§V), but every closed-loop figure replays a batch all at once.
This scenario feeds requests in at Poisson arrival times
(``Engine.run(..., open_loop=True)``) and reports TTFT/TBT measured from
the streamed ``RequestOutput``s — the latency a client would actually
see at a given offered load, per engine mode.

Arrival times live on the engine's virtual clock (idle gaps are
fast-forwarded), so the scenario is deterministic in shape and runs at
full speed regardless of the offered rate.

Two arms:

* ``open_loop_poisson`` — wall-clock TTFT/TBT at Poisson load (numbers
  vary across runners; NOT baseline-gated);
* ``open_loop_det`` — the same admission machinery driven by a counting
  clock (every ``now()`` reading advances a fixed virtual tick), so the
  TTFT percentiles are pure functions of the scheduling trace and CI can
  gate them exactly (``regression_gate.py``).  The arm also runs with
  the jit-dispatch sentinel enabled and reports post-warmup recompiles —
  the compiled-once guarantee, measured on a served workload.  Smoke
  mode (``--smoke``) runs only this arm.
"""
import dataclasses

import numpy as np

from benchmarks.common import make_requests, model_and_params, serve_cfg
from repro.core.engine import Engine

N_REQ, INPUT, OUTPUT = 10, 48, 12
RATES = (5.0, 50.0)          # offered load, requests per virtual second
MODES = ["sequential", "splitwiser_mps"]

# virtual seconds between deterministic-arm arrivals: a few engine steps
# apart under the counting clock, so admission happens mid-serve
DET_GAP = 0.01


class _CountingClock:
    """Deterministic time source: each reading advances one fixed tick,
    so latency metrics are pure functions of how many times the engine
    consulted the clock — identical on any runner."""

    def __init__(self, tick: float = 1e-4):
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t


def _agg(vals):
    vals = [v for v in vals if v is not None]
    if not vals:
        return None, None
    return (round(float(np.mean(vals)), 4),
            round(float(np.median(vals)), 4))


def _vp(vals, q):
    vals = [v for v in vals if v is not None]
    return None if not vals else round(float(np.percentile(vals, q)), 4)


def _poisson_rows(model, params, V):
    out = []
    for mode in MODES:
        sc = serve_cfg(mode, n_requests=N_REQ, input_tokens=INPUT,
                       output_tokens=OUTPUT, max_batch=8)
        Engine(model, params, sc).run(       # compile outside the timed runs
            make_requests(2, INPUT, 2, V), max_steps=200)
        for rate in RATES:
            rng = np.random.default_rng(0)
            arrivals = np.cumsum(rng.exponential(1.0 / rate, size=N_REQ))
            eng = Engine(model, params, sc)
            reqs = make_requests(N_REQ, INPUT, OUTPUT, V, arrivals=arrivals)
            events = list(eng.stream(reqs, open_loop=True, max_steps=100_000))
            outputs = eng.poll()
            by_rid = {o.rid: o for o in outputs}
            firsts = {e.rid: e.t for e in events if e.first}
            ttft_mean, ttft_p50 = _agg([o.ttft for o in outputs])
            tbt_mean, _ = _agg([o.tbt for o in outputs])
            out.append(dict(
                bench="open_loop_poisson", x=f"{mode}@{rate:g}rps",
                n_requests=N_REQ, n_done=len(outputs),
                all_finished_by_length=all(
                    o.finish_reason == "length" for o in outputs),
                respects_arrivals=all(
                    firsts[o.rid] >= o.arrival for o in outputs),
                arrival_span_s=round(float(arrivals[-1]), 3),
                ttft_mean=ttft_mean, ttft_p50=ttft_p50, tbt_mean=tbt_mean,
                n_preempted=sum(by_rid[r].n_preempted for r in by_rid),
            ))
    return out


def _det_rows(model, params, V):
    out = []
    arrivals = [i * DET_GAP for i in range(N_REQ)]
    for mode in MODES:
        sc = dataclasses.replace(
            serve_cfg(mode, n_requests=N_REQ, input_tokens=INPUT,
                      output_tokens=OUTPUT, max_batch=8),
            dispatch_sentinel=True)
        eng = Engine(model, params, sc, time_fn=_CountingClock())
        # two warmup replays on the same engine: the first compiles the
        # cold-cache shapes, the second the warm-prefix-cache shapes the
        # measured run will see — only then is "compiled once" checkable
        for base in (1000, 2000):
            warm = make_requests(N_REQ, INPUT, OUTPUT, V, arrivals=arrivals)
            for r in warm:
                r.rid += base
            eng.run(warm, open_loop=True, max_steps=100_000)
        eng.poll()
        eng.dispatch.mark_warm()
        reqs = make_requests(N_REQ, INPUT, OUTPUT, V, arrivals=arrivals)
        events = list(eng.stream(reqs, open_loop=True, max_steps=100_000))
        outputs = eng.poll()
        firsts = {e.rid: e.t for e in events if e.first}
        ttfts = [o.ttft for o in outputs]
        out.append(dict(
            bench="open_loop_det", x=mode,
            n_requests=N_REQ, n_done=len(outputs),
            all_complete=all(o.finish_reason == "length" for o in outputs),
            respects_arrivals=all(
                firsts[o.rid] >= o.arrival for o in outputs),
            # virtual-clock percentiles: deterministic, baseline-gated
            ttft_vp50=_vp(ttfts, 50), ttft_vp95=_vp(ttfts, 95),
            n_preempted=sum(o.n_preempted for o in outputs),
            dispatch_post_warm=sum(
                eng.dispatch.post_warm_compiles().values()),
        ))
    return out


def rows(smoke: bool = False):
    model, params = model_and_params("opt-125m")
    V = model.cfg.vocab_size
    det = _det_rows(model, params, V)
    if smoke:
        return det
    return _poisson_rows(model, params, V) + det
