"""Paper Figs. 2-4: per-phase compute/bandwidth character.

The paper measured SM%/DRAM% with ncu on an A10. Our TPU-target analogue
derives, from the loop-aware cost model on the FULL opt-125m config (the
paper's model), each phase's FLOPs, bytes and arithmetic intensity as a
function of input/output token counts — showing prefill crossing the v5e
ridge point (compute-bound) while decode stays far below it
(bandwidth-bound). This is the quantitative motivation for Splitwiser.
"""
import jax
import jax.numpy as jnp

from repro.common.hw import TPU_V5E
from repro.configs import get_config
from repro.launch.costs import traced_costs
from repro.models import transformer as T
from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model

RIDGE = TPU_V5E.peak_flops_bf16 / TPU_V5E.hbm_bw   # flops/byte ridge point


def rows():
    cfg = get_config("opt-125m")
    model = Model("opt-125m", cfg, FAMILY_MODULE[cfg.family],
                  CACHE_KIND[cfg.family])
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                               jnp.bfloat16))
    out = []
    # --- Fig 2 analogue: prefill intensity vs input tokens ---
    for s in [128, 256, 512, 1024, 2048]:
        toks = jax.ShapeDtypeStruct((1, s), jnp.int32)
        c = traced_costs(lambda p, t: T.prefill(p, cfg, t)[0], params, toks)
        ai = c["flops"] / max(c["bytes"], 1)
        out.append(dict(bench="fig2_prefill_intensity", x=s,
                        flops=c["flops"], bytes=c["bytes"],
                        arith_intensity=round(ai, 2),
                        compute_bound=bool(ai > RIDGE)))
    # --- Fig 3 analogue: decode intensity vs context length ---
    ps = 16
    for ctx in [128, 256, 512, 1024, 2048]:
        n_pages = ctx // ps + 4
        kpg = jax.ShapeDtypeStruct((cfg.n_layers, n_pages, ps,
                                    cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)
        bt = jax.ShapeDtypeStruct((1, ctx // ps + 1), jnp.int32)
        lens = jax.ShapeDtypeStruct((1,), jnp.int32)
        tok = jax.ShapeDtypeStruct((1,), jnp.int32)
        c = traced_costs(
            lambda p, t, k, v, b, l: T.decode(p, cfg, t, k, v, b, l)[0],
            params, tok, kpg, kpg, bt, lens)
        ai = c["flops"] / max(c["bytes"], 1)
        out.append(dict(bench="fig3_decode_intensity", x=ctx,
                        flops=c["flops"], bytes=c["bytes"],
                        arith_intensity=round(ai, 2),
                        compute_bound=bool(ai > RIDGE)))
    # --- Fig 4 analogue: batching decode raises intensity sub-linearly ---
    for b in [1, 5, 10, 20, 40]:
        ctx, n_pages = 512, (512 // ps + 2) * 40 + 4
        kpg = jax.ShapeDtypeStruct((cfg.n_layers, n_pages, ps,
                                    cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)
        bt = jax.ShapeDtypeStruct((b, ctx // ps + 1), jnp.int32)
        lens = jax.ShapeDtypeStruct((b,), jnp.int32)
        tok = jax.ShapeDtypeStruct((b,), jnp.int32)
        c = traced_costs(
            lambda p, t, k, v, bt_, l: T.decode(p, cfg, t, k, v, bt_, l)[0],
            params, tok, kpg, kpg, bt, lens)
        ai = c["flops"] / max(c["bytes"], 1)
        out.append(dict(bench="fig4_decode_batch_intensity", x=b,
                        flops=c["flops"], bytes=c["bytes"],
                        arith_intensity=round(ai, 2),
                        compute_bound=bool(ai > RIDGE)))
    return out
