"""CI benchmark-regression gate.

Compares a fresh ``benchmarks/run.py --json`` output against the
committed ``BENCH_baseline.json`` and fails (exit 1) when a guarded
metric regresses beyond tolerance — so a PR that silently lowers the
prefix-cache hit rate, recomputes more prefill tokens, or stops
completing requests is caught by CI instead of landing.

Only *deterministic* fields are gated (hit rates, token counts,
completion counts); timing fields (throughput, TTFT) vary across
runners and are deliberately ignored.  Rows are keyed by
``(bench, x)``; a row present in the baseline but missing from the
fresh run fails the gate (a scenario was dropped), new rows pass
freely (they have no baseline yet).  Validation checks recorded in the
baseline must not flip from pass to fail.

    # refresh the committed baseline after an intentional change:
    PYTHONPATH=src python -m benchmarks.run --smoke \
        --only shared_prefix,pressure,policy_sweep,open_loop,mixed_longprompt,slo_tenants \
        --json BENCH_baseline.json

    # what CI runs on every PR:
    PYTHONPATH=src python -m benchmarks.run --smoke \
        --only shared_prefix,pressure,policy_sweep,open_loop,mixed_longprompt,slo_tenants \
        --json bench_fresh.json
    PYTHONPATH=src python -m benchmarks.regression_gate \
        BENCH_baseline.json bench_fresh.json
"""
import argparse
import json
import sys

# field -> (direction, kind): "min" fails when fresh < base - tol,
# "max" fails when fresh > base + tol.  "rate" fields use the absolute
# hit-rate tolerance; "count" fields use the relative count tolerance.
GATED_FIELDS = {
    "hit_rate": ("min", "rate"),
    "hit_rate_on": ("min", "rate"),
    "hit_rate_token": ("min", "rate"),
    "n_done": ("min", "count"),
    "cached_tokens": ("min", "count"),
    "prefill_tokens": ("max", "count"),
    "prefill_tokens_token": ("max", "count"),
    "prefill_tokens_saved": ("min", "count"),
    "n_partial_hits": ("min", "count"),
    # scheduler-health counters (pressure + policy_sweep rows): these are
    # deterministic, and growth means thrash — a scheduler change that
    # preempts or reclaims more to finish the same workload is a
    # regression even when completion counts hold
    "n_preemptions": ("max", "count"),
    "n_preempted_requests": ("max", "count"),
    "n_reclaims": ("max", "count"),
    # open_loop_det rows: TTFT percentiles on the counting clock are pure
    # functions of the scheduling trace, so they gate exactly like counts
    # (a scheduler change that delays first tokens shows up here), and a
    # post-warmup recompile breaks the compiled-once guarantee outright
    "ttft_vp50": ("max", "count"),
    "ttft_vp95": ("max", "count"),
    "n_preempted": ("max", "count"),
    "dispatch_post_warm": ("max", "count"),
    # mixed_longprompt_det rows: inter-token-gap percentiles on the
    # work-proportional clock — the chunked mode's whole reason to exist
    # is the p95/p99 bound, so a scheduler change that lets a long
    # prompt stall decodes again fails here; completed tokens guard
    # against "faster" runs that simply generated less
    "tbt_vp50": ("max", "count"),
    "tbt_vp95": ("max", "count"),
    "tbt_vp99": ("max", "count"),
    "completed_tokens": ("min", "count"),
    # int8 KV rows (pressure_kv_int8 / shared_prefix_int8_delta): the
    # byte-denominated pool's page multiplier must not shrink, the int8
    # arm must not start preempting, and its prefix-cache hit capacity
    # on the tight pool must not fall back to the fp arm's level
    "page_ratio": ("min", "count"),
    "preemptions_int8": ("max", "count"),
    "cached_tokens_int8": ("min", "count"),
    "hit_rate_int8": ("min", "rate"),
    # slo_tenants rows: counting-clock per-tenant percentiles and SLO
    # attainment fractions are deterministic scheduling-trace functions —
    # a policy change that lets the burst tenant head-of-line block gold
    # again shows up as an attainment drop or a tail-percentile rise
    "slo_attainment": ("min", "rate"),
    "gold_attainment": ("min", "rate"),
    "silver_attainment": ("min", "rate"),
    "attainment_deadline": ("min", "rate"),
    "gold_ttft_vp50": ("max", "count"),
    "gold_ttft_vp99": ("max", "count"),
    "gold_tbtmax_vp99": ("max", "count"),
    "silver_ttft_vp99": ("max", "count"),
    "gold_p99_deadline": ("max", "count"),
    "quota_holds": ("min", "count"),
}
# must not flip true -> false (seed_crash rows record True: the
# oversubscribed pool *must* crash the seed admission policy;
# attainment/victim improvement booleans are the slo_tenants headline)
BOOL_FIELDS = ("all_complete", "tokens_match", "seed_crash",
               "respects_arrivals", "attainment_improved",
               "victim_p99_improved")


def _rows_by_key(report: dict) -> dict:
    return {(r["bench"], r["x"]): r for r in report.get("rows", [])}


def compare(baseline: dict, fresh: dict, *, hit_rate_tol: float = 0.02,
            count_tol: float = 0.02) -> list:
    """Returns a list of human-readable regression strings (empty = pass)."""
    failures = []
    base_rows, fresh_rows = _rows_by_key(baseline), _rows_by_key(fresh)
    for key, base in base_rows.items():
        row = fresh_rows.get(key)
        if row is None:
            failures.append(f"{key}: scenario missing from fresh run")
            continue
        for field, (direction, kind) in GATED_FIELDS.items():
            if field not in base or base[field] is None:
                continue
            b, f = base[field], row.get(field)
            if f is None:
                failures.append(f"{key}: field {field} missing from fresh run")
                continue
            tol = hit_rate_tol if kind == "rate" else count_tol * max(abs(b), 1)
            if (direction == "min" and f < b - tol) or \
                    (direction == "max" and f > b + tol):
                failures.append(
                    f"{key}: {field} regressed {b} -> {f} (tol {tol:.4g})")
        for field in BOOL_FIELDS:
            if base.get(field) is True and row.get(field) is not True:
                failures.append(
                    f"{key}: {field} flipped {base[field]} -> {row.get(field)}")
    base_checks = {c["msg"]: c["passed"] for c in baseline.get("checks", [])}
    fresh_checks = {c["msg"]: c["passed"] for c in fresh.get("checks", [])}
    for msg, passed in base_checks.items():
        if not passed:
            continue
        if msg not in fresh_checks:
            # a reworded/removed check must regenerate the baseline, not
            # silently stop guarding what it checked
            failures.append(f"validation check missing from fresh run: {msg}")
        elif fresh_checks[msg] is not True:
            failures.append(f"validation check now failing: {msg}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("fresh", help="fresh benchmarks/run.py --json output")
    ap.add_argument("--hit-rate-tol", type=float, default=0.02,
                    help="absolute tolerance on cache hit rates")
    ap.add_argument("--count-tol", type=float, default=0.02,
                    help="relative tolerance on token/completion counts")
    args = ap.parse_args()
    with open(args.baseline) as fp:
        baseline = json.load(fp)
    with open(args.fresh) as fp:
        fresh = json.load(fp)
    failures = compare(baseline, fresh, hit_rate_tol=args.hit_rate_tol,
                       count_tol=args.count_tol)
    n = len(_rows_by_key(baseline))
    if failures:
        print(f"BENCHMARK REGRESSION: {len(failures)} failure(s) "
              f"across {n} baseline rows")
        for f in failures:
            print(f"  [FAIL] {f}")
        sys.exit(1)
    print(f"benchmark gate ok: {n} baseline rows within tolerance")


if __name__ == "__main__":
    main()
