"""Small pytree utilities (no flax/optax in this environment)."""
import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_map_with_path(fn, tree):
    return jax.tree_util.tree_map_with_path(fn, tree)


def path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)
