"""Target-hardware constants used by the roofline analysis.

The runtime container is CPU-only; TPU v5e is the *target*. All roofline
terms in EXPERIMENTS.md are derived from compiled HLO + these constants.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float   # FLOP/s per chip
    hbm_bw: float            # bytes/s per chip
    ici_bw_per_link: float   # bytes/s per link (one direction)
    ici_links: int           # links per chip in the 2D torus
    hbm_bytes: int           # HBM capacity per chip
    vmem_bytes: int          # VMEM per core


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=4,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)
