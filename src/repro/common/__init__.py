from repro.common.hw import TPU_V5E
from repro.common.tree import tree_bytes, tree_count, tree_cast, tree_map_with_path
