"""Deterministic synthetic data pipeline.

Training: a seeded, step-indexed token stream with local n-gram structure
(so the LM loss genuinely decreases — pure-uniform tokens would not train).
Determinism in `step` is what makes checkpoint/restart bitwise reproducible
and is the straggler-/failure-safe property real pipelines need (any host
can recompute any step's shard without coordination).

Serving: synthetic radiology-report-shaped prompts standing in for the
paper's MIMIC-III CT/MR reports (30k de-identified notes; we generate
matched-length synthetic text instead — no clinical data in the repo).
"""
from __future__ import annotations

import hashlib
from typing import List

import numpy as np


# ------------------------------------------------------------- training ----
def _rng_for(seed: int, step: int) -> np.random.Generator:
    mix = int.from_bytes(
        hashlib.blake2s(f"{seed}:{step}".encode(), digest_size=8).digest(), "little")
    return np.random.default_rng(mix)


def lm_batch(step: int, *, batch: int, seq: int, vocab: int, seed: int = 0,
             order: int = 3):
    """Markov-ish synthetic tokens [batch, seq+1] -> (tokens, labels)."""
    rng = _rng_for(seed, step)
    # deterministic per-seed transition structure: next = f(prev) + noise
    a = (seed * 2654435761 + 97) % vocab
    toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int64)
    for t in range(1, seq + 1):
        follow = (toks[:, t - 1] * 31 + a) % vocab
        use = rng.random(batch) < 0.85
        toks[:, t] = np.where(use, follow, toks[:, t])
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def make_train_data_fn(cfg, tcfg, extra: str = ""):
    """step -> batch dict for the arch's family (adds frames/patches stubs)."""
    import jax.numpy as jnp

    def fn(step: int):
        b = lm_batch(step, batch=tcfg.global_batch, seq=tcfg.seq_len,
                     vocab=cfg.vocab_size, seed=tcfg.seed)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "encdec":
            rng = _rng_for(tcfg.seed + 1, step)
            out["frames"] = jnp.asarray(
                rng.standard_normal((tcfg.global_batch, cfg.encoder_seq,
                                     cfg.d_model), dtype=np.float32) * 0.3)
        if cfg.family == "vlm":
            rng = _rng_for(tcfg.seed + 2, step)
            out["patches"] = jnp.asarray(
                rng.standard_normal((tcfg.global_batch, cfg.n_vision_patches,
                                     cfg.d_vision), dtype=np.float32) * 0.3)
        return out

    return fn


# -------------------------------------------------------------- serving ----
_SECTIONS = ["EXAMINATION", "INDICATION", "TECHNIQUE", "COMPARISON",
             "FINDINGS", "IMPRESSION"]
_FINDINGS = [
    "no acute intracranial abnormality", "mild mucosal thickening",
    "stable postsurgical changes", "no evidence of pulmonary embolism",
    "scattered calcified granulomas", "unremarkable soft tissues",
    "no focal consolidation", "trace pleural effusion",
    "degenerative changes of the spine", "patent major vessels",
]


def synthetic_reports(n: int, seed: int = 0) -> List[str]:
    """Synthetic CT/MR report text shaped like the paper's MIMIC-III data."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        parts = []
        for s in _SECTIONS:
            k = int(rng.integers(1, 4))
            body = "; ".join(rng.choice(_FINDINGS, size=k))
            parts.append(f"{s}: {body}.")
        out.append(f"Report {i}. " + " ".join(parts))
    return out


def report_tokens(n: int, length: int, vocab: int, seed: int = 0):
    """Tokenized prompts: hash-tokenizer over synthetic reports, padded or
    cycled to exactly `length` tokens (the paper controls input-token count
    explicitly — §III-A1)."""
    texts = synthetic_reports(n, seed)
    out = []
    for t in texts:
        words = t.split()
        ids = [(int.from_bytes(hashlib.blake2s(w.encode(), digest_size=4)
                               .digest(), "little") % (vocab - 2)) + 2
               for w in words]
        while len(ids) < length:
            ids = ids + ids
        out.append(ids[:length])
    return out


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    return np.cumsum(gaps)
