from repro.data.synthetic import (
    lm_batch, make_train_data_fn, synthetic_reports, report_tokens,
    poisson_arrivals,
)
