"""Cross-entropy LM losses.

`lm_loss_from_hidden` never materializes [B, T, V] logits: it scans over
sequence chunks computing logsumexp + the label logit via an iota mask
(vocab-shard-friendly: no gather across the sharded vocab dim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -100


def _chunk_ce(hidden_c, labels_c, table, softcap, v_real):
    """hidden_c [B, c, D]; labels_c [B, c] -> per-token loss [B, c]."""
    logits = jnp.einsum("bcd,vd->bcv", hidden_c, table,
                        preferred_element_type=jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    Vp = table.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, Vp), 2)
    if Vp != v_real:
        logits = jnp.where(iota < v_real, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.where(labels_c[..., None] == iota, logits, 0.0).sum(-1)
    return lse - lab


def lm_loss_from_hidden(hidden, labels, table, *, softcap=None, v_real=None,
                        chunk=512):
    """hidden [B, T, D]; labels [B, T] (IGNORE = masked).

    Returns (mean_loss, n_tokens).
    """
    B, T, D = hidden.shape
    v_real = v_real or table.shape[0]
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=IGNORE)
    n = (T + pad) // c
    hc = hidden.reshape(B, n, c, D).swapaxes(0, 1)
    lc = labels.reshape(B, n, c).swapaxes(0, 1)

    def body(carry, xs):
        tot, cnt = carry
        h, l = xs
        mask = l != IGNORE
        ce = _chunk_ce(h, jnp.where(mask, l, 0), table, softcap, v_real)
        return (tot + jnp.sum(ce * mask), cnt + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0), cnt


# ------------------------------------------------- vocab-tiled fused CE ----
# §Perf optimization: the chunked-over-TOKENS loss above still materializes
# [B, chunk, V] logits in HBM — at V=152k that traffic DOMINATES small-model
# training (measured: qwen3-0.6b train_4k memory term 0.35s, ~70% of it
# loss logits). This version scans over VOCAB tiles with an online
# logsumexp, so logits tiles live in VMEM (tagged *_vmem_body; the Pallas
# realization is a standard fused-CE kernel). HBM traffic drops to
# ~(table + hidden) reads per pass. Backward is hand-written as another
# vocab-tiled scan (custom_vjp), same property.
import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _vtiled_ce(hidden2d, labels1d, table, softcap, v_real, vtile):
    out, _ = _vtiled_ce_fwd(hidden2d, labels1d, table, softcap, v_real, vtile)
    return out


def _tiles(table, vtile):
    Vp, D = table.shape
    if Vp % vtile:
        raise ValueError(
            f"padded vocab {Vp} must be a multiple of vtile={vtile}")
    return table.reshape(Vp // vtile, vtile, D)


def _vtiled_ce_fwd(hidden2d, labels1d, table, softcap, v_real, vtile):
    """hidden2d [N, D] f32-able; labels1d [N] (IGNORE masked outside).

    Returns per-token (lse - label_logit) [N].
    """
    N, D = hidden2d.shape
    tiles = _tiles(table, vtile)
    nt = tiles.shape[0]
    h = hidden2d.astype(jnp.float32)

    def ce_fwd_vmem_body(carry, xs):
        m, s, lab = carry
        tbl, ti = xs
        v0 = ti * vtile
        logits = jnp.einsum("nd,vd->nv", h, tbl.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        ids = v0 + jax.lax.broadcasted_iota(jnp.int32, (1, vtile), 1)
        logits = jnp.where(ids < v_real, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(logits - m_new[:, None]).sum(-1)
        lab = lab + jnp.where(ids == labels1d[:, None], logits, 0.0).sum(-1)
        return (m_new, s, lab), None

    m0 = jnp.full((N,), -1e30, jnp.float32)
    (m, s, lab), _ = jax.lax.scan(
        ce_fwd_vmem_body, (m0, jnp.zeros((N,), jnp.float32),
                           jnp.zeros((N,), jnp.float32)),
        (tiles, jnp.arange(nt, dtype=jnp.int32)))
    lse = m + jnp.log(jnp.maximum(s, 1e-30))
    return lse - lab, (hidden2d, labels1d, table, lse)


def _vtiled_ce_bwd(softcap, v_real, vtile, res, g):
    hidden2d, labels1d, table, lse = res
    N, D = hidden2d.shape
    tiles = _tiles(table, vtile)
    nt = tiles.shape[0]
    h = hidden2d.astype(jnp.float32)
    gf = g.astype(jnp.float32)

    def ce_bwd_vmem_body(dh, xs):
        tbl, ti = xs
        v0 = ti * vtile
        tblf = tbl.astype(jnp.float32)
        logits = jnp.einsum("nd,vd->nv", h, tblf,
                            preferred_element_type=jnp.float32)
        dcap = 1.0
        if softcap:
            t = jnp.tanh(logits / softcap)
            logits_c = t * softcap
            dcap = 1.0 - jnp.square(t)       # d logits_c / d logits
        else:
            logits_c = logits
        ids = v0 + jax.lax.broadcasted_iota(jnp.int32, (1, vtile), 1)
        valid = ids < v_real
        p = jnp.where(valid, jnp.exp(logits_c - lse[:, None]), 0.0)
        onehot = (ids == labels1d[:, None]).astype(jnp.float32)
        dlogits = gf[:, None] * (p - onehot) * dcap     # [N, vtile]
        dh = dh + jnp.einsum("nv,vd->nd", dlogits, tblf)
        dtbl = jnp.einsum("nv,nd->vd", dlogits, h).astype(table.dtype)
        return dh, dtbl

    dh, dtiles = jax.lax.scan(
        ce_bwd_vmem_body, jnp.zeros((N, D), jnp.float32),
        (tiles, jnp.arange(nt, dtype=jnp.int32)))
    dtable = dtiles.reshape(table.shape)
    return dh.astype(hidden2d.dtype), None, dtable


_vtiled_ce.defvjp(_vtiled_ce_fwd, _vtiled_ce_bwd)


def lm_loss_from_hidden_vtiled(hidden, labels, table, *, softcap=None,
                               v_real=None, vtile=8192):
    """Drop-in for lm_loss_from_hidden with vocab-tiled fused CE."""
    B, T, D = hidden.shape
    v_real = v_real or table.shape[0]
    vtile = min(vtile, table.shape[0])
    while table.shape[0] % vtile:
        vtile //= 2
    mask = labels != IGNORE
    lab = jnp.where(mask, labels, 0).reshape(-1)
    ce = _vtiled_ce(hidden.reshape(B * T, D), lab, table,
                    float(softcap) if softcap else 0.0, int(v_real),
                    int(vtile))
    ce = ce.reshape(B, T)
    n = jnp.sum(mask)
    return jnp.sum(ce * mask) / jnp.maximum(n, 1.0), n


def lm_loss(logits, labels, *, v_real=None):
    """Full-logit CE (small models / non-transformer families)."""
    v_real = v_real or logits.shape[-1]
    mask = labels != IGNORE
    lab = jnp.where(mask, labels, 0)
    logits = logits.astype(jnp.float32)
    Vp = logits.shape[-1]
    if Vp != v_real:
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, Vp), 2)
        logits = jnp.where(iota < v_real, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    ce = lse - ll
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0), jnp.sum(mask)
