"""Training step + fault-tolerant Trainer loop.

make_train_step builds the jitted step for any registered arch:
  - transformer family -> chunked CE from hidden (no [B,T,V] logits);
  - other families     -> full-logit CE;
  - gradient accumulation via lax.scan over microbatches;
  - global-norm clipping, warmup-cosine LR, AdamW (optionally int8 moments);
  - MoE router aux-loss added with cfg.router_aux_coef.

Trainer adds checkpoint/restart fault tolerance: async sharded snapshots
every ckpt_every steps, resume-from-latest, and deterministic data order so
a killed-and-resumed run is bitwise identical to an uninterrupted one
(tests/test_train.py::test_failure_resume_bitwise).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, warmup_cosine
from repro.train.losses import (lm_loss, lm_loss_from_hidden,
                                lm_loss_from_hidden_vtiled)


def make_loss_fn(model, tcfg: TrainConfig, *, tp=1, policy=None, moe_fn=None):
    cfg = model.cfg

    def loss_fn(params, batch):
        if cfg.family in ("dense", "moe", "vlm"):
            hidden, aux = T.train_hidden(params, cfg, batch, tp=tp,
                                         policy=policy, moe_fn=moe_fn,
                                         remat=tcfg.remat)
            table = params["head"] if "head" in params else params["embed"]
            labels = batch["labels"]
            if cfg.family == "vlm":   # hidden includes the vision prefix
                npfx = hidden.shape[1] - labels.shape[1]
                labels = jnp.pad(labels, ((0, 0), (npfx, 0)),
                                 constant_values=-100)
            loss_fn_impl = (lm_loss_from_hidden_vtiled
                            if tcfg.loss_impl == "vtiled"
                            else lm_loss_from_hidden)
            loss, n = loss_fn_impl(
                hidden, labels, table, softcap=cfg.final_logit_softcap,
                v_real=cfg.vocab_size)
        else:
            logits, aux = model.module.train_logits(
                params, cfg, batch, tp=tp, policy=policy, remat=tcfg.remat)
            loss, n = lm_loss(logits, batch["labels"], v_real=cfg.vocab_size)
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * aux / cfg.n_layers
        return loss, n

    return loss_fn


def make_train_step(model, tcfg: TrainConfig, *, tp=1, policy=None,
                    moe_fn=None):
    loss_fn = make_loss_fn(model, tcfg, tp=tp, policy=policy, moe_fn=moe_fn)

    def compute_grads(params, batch):
        if tcfg.microbatch and tcfg.microbatch < batch["tokens"].shape[0]:
            B = batch["tokens"].shape[0]
            m = tcfg.microbatch
            n_micro = B // m
            mb = jax.tree.map(
                lambda x: x.reshape((n_micro, m) + x.shape[1:]), batch)

            def body(carry, micro):
                acc, ltot = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, micro)
                return (jax.tree.map(jnp.add, acc, g), ltot + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, ltot), _ = jax.lax.scan(body, (zero, jnp.float32(0.0)), mb)
            g = jax.tree.map(lambda x: x / n_micro, g)
            return ltot / n_micro, g
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return l, g

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        loss, grads = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = warmup_cosine(opt["count"], base_lr=tcfg.lr,
                           warmup_steps=tcfg.warmup_steps,
                           total_steps=tcfg.total_steps)
        new_params, new_opt = adamw_update(
            grads, opt, params, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "gnorm": gnorm, "lr": lr})

    return train_step


def init_state(model, key, tcfg: TrainConfig, dtype=jnp.float32, tp=1):
    params = model.init(key, dtype, tp=tp)
    return {"params": params, "opt": adamw_init(params, tcfg.int8_moments)}


@dataclass
class Trainer:
    """Fault-tolerant training loop (checkpoint / restart / resume)."""
    model: object
    tcfg: TrainConfig
    data_fn: Callable[[int], dict]      # step -> batch (deterministic!)
    tp: int = 1
    policy: Optional[object] = None
    log_every: int = 10

    def __post_init__(self):
        from repro.ckpt.checkpoint import AsyncCheckpointer, latest_step, load
        self._step_fn = jax.jit(make_train_step(self.model, self.tcfg,
                                                tp=self.tp, policy=self.policy))
        self.ckpt = AsyncCheckpointer(self.tcfg.ckpt_dir)
        start = latest_step(self.tcfg.ckpt_dir)
        if start is not None:
            self.state = load(self.tcfg.ckpt_dir, start)
            self.start_step = start
        else:
            self.state = init_state(self.model, jax.random.PRNGKey(self.tcfg.seed),
                                    self.tcfg, tp=self.tp)
            self.start_step = 0
        self.history = []

    def run(self, n_steps: Optional[int] = None, crash_at: Optional[int] = None):
        """Run to tcfg.total_steps (or n_steps more). crash_at simulates a
        node failure after that global step commits (for FT tests)."""
        end = self.tcfg.total_steps if n_steps is None else self.start_step + n_steps
        step = self.start_step
        while step < end:
            batch = self.data_fn(step)
            self.state, m = self._step_fn(self.state, batch)
            step += 1
            if step % self.tcfg.ckpt_every == 0 or step == end:
                self.ckpt.save(step, self.state)
            if step % self.log_every == 0 or step == end:
                self.history.append((step, float(m["loss"])))
            if crash_at is not None and step >= crash_at:
                self.ckpt.wait()
                raise RuntimeError(f"simulated failure at step {step}")
        self.ckpt.wait()
        self.start_step = step
        return self.history
