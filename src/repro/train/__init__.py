from repro.train.losses import lm_loss, lm_loss_from_hidden
from repro.train.trainer import Trainer, make_train_step
