"""Loop-aware cost model for the dry-run.

XLA's HloCostAnalysis visits each instruction ONCE — a lax.scan over 64
layers reports 1/64th of the real FLOPs (verified in
tests/test_roofline.py). We therefore derive:

  * FLOPs / major-op bytes: a jaxpr walk that multiplies scan bodies by
    their trip counts. Bytes counts operands+outputs of bandwidth-relevant
    ops (dots, gathers/scatters, convs, reduces) — the post-fusion
    approximation a TPU roofline uses (elementwise chains fuse into these).
  * collective bytes: parsed from the SPMD-partitioned HLO (per-shard
    operand shapes) with while-loop trip multipliers propagated through
    the call graph — covers both GSPMD-inserted collectives (TP
    all-reduces) and shard_map psums.

Per-device FLOPs/bytes = global / n_devices (valid because every heavy op
in the sharded design is partitioned; padding waste is *included* since
jaxpr shapes carry the padding).
"""
from __future__ import annotations

import re
from typing import Dict

import jax
import numpy as np

# ------------------------------------------------------------ jaxpr walk ---
_DOT_PRIMS = {"dot_general"}
_GATHER_PRIMS = {"gather", "scatter", "scatter-add", "scatter_add",
                 "dynamic_slice", "dynamic_update_slice", "take"}
_REDUCE_PRIMS = {"reduce_sum", "reduce_max", "reduce_min", "argmax", "argmin",
                 "cumsum", "cumlogsumexp", "reduce_prod", "sort"}
_CONV_PRIMS = {"conv_general_dilated"}
_EW_FLOP_PRIMS = {"exp", "tanh", "log", "erf", "logistic", "rsqrt", "sqrt"}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    m = int(np.prod([a.shape[i] for i in range(a.ndim)
                     if i not in set(lc) | set(lb)]))
    k = int(np.prod([a.shape[i] for i in lc]))
    batch = int(np.prod([a.shape[i] for i in lb])) if lb else 1
    n = int(np.prod([b.shape[i] for i in range(b.ndim)
                     if i not in set(rc) | set(rb)]))
    return 2 * batch * m * n * k


def jaxpr_costs(jaxpr, outer_mult: int = 1) -> Dict[str, float]:
    """Walk a (closed) jaxpr; returns dict(flops=..., bytes=...).

    FLOPs include remat recompute (executed work, not model work — the
    useful_ratio in the roofline table exposes the difference). Gathers
    whose output feeds directly into a tagged VMEM scan are not
    byte-counted (the scan's stream-IO accounting covers that read once).

    outer_mult: replication factor for work OUTSIDE shard_map regions
    (e.g. decode schemes that replicate GEMM activations over the data
    axis execute that work on every data shard; shard_map interiors are
    already exact via the mesh-size multiplier). Divide the result by
    n_devices for per-device costs.
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    flops = 0.0
    byts = 0.0

    # vars consumed as inputs by tagged vmem scans (stream-IO covers them),
    # traced transitively back through layout-only ops (reshape/transpose/
    # convert) so a gather feeding flash via a reshape isn't double-counted
    _LAYOUT = {"reshape", "transpose", "convert_element_type", "squeeze",
               "expand_dims", "rev"}
    producer = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            producer[id(v)] = eqn
    vmem_fed = set()
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            sub = eqn.params["jaxpr"]
            dbg = str(getattr(getattr(sub, "jaxpr", sub), "debug_info", ""))
            if "vmem_body" in dbg:
                stack = list(eqn.invars)
                while stack:
                    v = stack.pop()
                    if id(v) in vmem_fed:
                        continue
                    vmem_fed.add(id(v))
                    src = producer.get(id(v))
                    if src is not None and src.primitive.name in _LAYOUT:
                        stack.extend(src.invars)

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub = None
        mult = 1
        if prim == "scan":
            sub = eqn.params["jaxpr"]
            mult = eqn.params["length"] * eqn.params.get("num_trips", 1)
            # kernel-resident scan bodies (flash attention / SSM chunk
            # scans, tagged "*_vmem_body") get stream-IO byte accounting:
            # their interiors live in VMEM on the TPU target (that is what
            # the Pallas kernels implement), so HBM bytes = scan inputs +
            # outputs (Q/K/V/O-style), while FLOPs recurse normally.
            dbg = str(getattr(getattr(sub, "jaxpr", sub), "debug_info", ""))
            if "vmem_body" in dbg:
                inner = jaxpr_costs(sub, outer_mult=1)
                flops += mult * inner["flops"] * outer_mult
                byts += outer_mult * sum(_nbytes(v.aval) for v in eqn.invars)
                byts += outer_mult * sum(_nbytes(v.aval) for v in eqn.outvars)
                continue
        elif prim == "while":
            sub = eqn.params["body_jaxpr"]
            mult = _while_trips(eqn)
        elif prim in ("pjit", "jit", "closed_call", "core_call",
                      "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "remat", "remat2",
                      "checkpoint", "custom_lin"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        elif prim in ("shard_map", "smap"):
            sub = eqn.params.get("jaxpr")
            # inner shapes are per-shard; scale back to global totals
            # (exact — so the outer replication factor does not apply)
            mult = int(np.prod([v for v in
                                getattr(eqn.params.get("mesh"), "shape",
                                        {}).values()])) or 1
            if sub is not None:
                c = jaxpr_costs(sub, outer_mult=1)
                flops += mult * c["flops"]
                byts += mult * c["bytes"]
            continue
        elif prim == "cond":
            subs = eqn.params.get("branches", ())
            if subs:
                cs = [jaxpr_costs(s) for s in subs]
                flops += max(c["flops"] for c in cs)
                byts += max(c["bytes"] for c in cs)
            continue

        if sub is not None:
            c = jaxpr_costs(sub, outer_mult=outer_mult)
            flops += mult * c["flops"]
            byts += mult * c["bytes"]
            continue

        if prim in _DOT_PRIMS:
            flops += _dot_flops(eqn) * outer_mult
            byts += outer_mult * (sum(_nbytes(v.aval) for v in eqn.invars)
                                  + sum(_nbytes(v.aval) for v in eqn.outvars))
        elif prim in _CONV_PRIMS:
            out = eqn.outvars[0].aval
            w = eqn.invars[1].aval
            flops += 2 * int(np.prod(out.shape)) * int(np.prod(w.shape[:-1])) * outer_mult
            byts += outer_mult * (sum(_nbytes(v.aval) for v in eqn.invars)
                                  + _nbytes(out))
        elif prim in ("scatter", "scatter-add", "scatter_add",
                      "dynamic_update_slice"):
            # a scatter's HBM write is the UPDATE bytes, not the whole pool
            upd_idx = 2 if prim.startswith("scatter") else 1
            if len(eqn.invars) > upd_idx:
                byts += _nbytes(eqn.invars[upd_idx].aval) * outer_mult
        elif prim in _GATHER_PRIMS:
            if not any(id(v) in vmem_fed for v in eqn.outvars):
                byts += outer_mult * sum(_nbytes(v.aval) for v in eqn.outvars)
            byts += outer_mult * sum(_nbytes(v.aval) for v in eqn.invars[1:2])
        elif prim in _REDUCE_PRIMS:
            byts += outer_mult * sum(_nbytes(v.aval) for v in eqn.invars)
            flops += outer_mult * sum(
                _nbytes(v.aval) // max(v.aval.dtype.itemsize, 1)
                for v in eqn.invars)
        elif prim in _EW_FLOP_PRIMS:
            n = sum(int(np.prod(v.aval.shape)) for v in eqn.outvars)
            flops += 4 * n * outer_mult

    return {"flops": flops, "bytes": byts}


def _while_trips(eqn) -> int:
    # raw while loops are rare in our code (scan covers them); assume 1
    return 1


def traced_costs(fn, *args) -> Dict[str, float]:
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_costs(closed)


# ---------------------------------------------- HLO collective accounting --
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL = re.compile(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                   r"collective-permute)(?:-start)?\(")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo_collectives(hlo: str) -> Dict[str, Dict]:
    """Per-computation collective operand bytes + call graph + trip counts.

    Returns {comp_name: {"coll": {kind: bytes}, "calls": [(name, kind)],
    "max_const": int}} where kind is "while_body" for loop bodies.
    """
    comps: Dict[str, Dict] = {}
    cur = None
    for line in hlo.splitlines():
        # computation headers sit at column 0 and end with '{'
        if not line[:1].isspace() and line.rstrip().endswith("{") and "(" in line:
            head = line.strip()
            is_entry = head.startswith("ENTRY")
            name = head.split("(", 1)[0].replace("ENTRY", "").strip().lstrip("%")
            cur = name
            comps[cur] = {"coll": {}, "calls": [], "max_const": 1,
                          "is_entry": is_entry}
            continue
        if cur is None:
            continue
        for cm in re.finditer(r"constant\((\d+)\)", line):
            comps[cur]["max_const"] = max(comps[cur]["max_const"],
                                          int(cm.group(1)))
        if "while(" in line:
            cm_body = re.search(r"body=%?([\w.\-]+)", line)
            cm_cond = re.search(r"condition=%?([\w.\-]+)", line)
            if cm_body and cm_cond:
                comps[cur]["calls"].append((cm_body.group(1), "while_body"))
                comps[cur]["calls"].append(
                    (cm_cond.group(1), "cond_of:" + cm_body.group(1)))
        for attr in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", line):
            comps[cur]["calls"].append((attr.group(1), "call"))
        km = _COLL.search(line)
        if km:
            kind = km.group(1)
            if re.search(r"-done\(", line):
                continue
            # operand bytes: for all-gather/all-to-all the operand(s) are the
            # per-shard input; use the smaller of operand/result per spec.
            args = line[km.end():]
            depth, out = 1, []
            for ch in args:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                out.append(ch)
            ops = "".join(out)
            b = _shape_bytes(ops)
            if b == 0:
                b = _shape_bytes(line.split("=", 1)[1].split("(", 1)[0])
            comps[cur]["coll"][kind] = comps[cur]["coll"].get(kind, 0) + b
    return comps


def collective_bytes_loop_aware(hlo: str) -> Dict[str, int]:
    """Total per-device collective bytes with while-trip multipliers."""
    comps = parse_hlo_collectives(hlo)
    entry = next((n for n, c in comps.items() if c.get("is_entry")), None)
    if entry is None and comps:
        entry = next(iter(comps))
    total: Dict[str, int] = {}
    trips_of = {}
    for c in comps.values():
        for callee, kind in c["calls"]:
            if kind.startswith("cond_of:"):
                body = kind.split(":", 1)[1]
                trips_of[body] = max(trips_of.get(body, 1),
                                     comps.get(callee, {}).get("max_const", 1))

    seen = set()

    def visit(name, mult):
        if name not in comps or (name, mult) in seen:
            return
        seen.add((name, mult))
        c = comps[name]
        for kind, b in c["coll"].items():
            total[kind] = total.get(kind, 0) + b * mult
        for callee, kind in c["calls"]:
            if kind == "while_body":
                visit(callee, mult * trips_of.get(callee, 1))
            elif kind == "call":
                visit(callee, mult)

    if entry:
        visit(entry, 1)
    return total
