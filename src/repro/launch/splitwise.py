"""Cross-pod phase disaggregation — the Splitwise [5] baseline on the
multi-pod mesh, for comparison against same-chip Splitwiser.

Pod 0 runs the prompt phase (prefill program), pod 1 the token phase
(decode program); the KV cache handles off over the pod interconnect.
This module builds BOTH programs on their pod submeshes, lowers+compiles
them, and reports the handoff cost per request — the quantity Splitwiser
eliminates by co-locating the phases (paper §I: "minimize network-related
overheads").

    PYTHONPATH=src python -m repro.launch.splitwise --arch qwen3-0.6b
"""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse

import numpy as np


def analyze_splitwise(arch: str, *, seq=32768, prefill_batch=32,
                      decode_batch=128, verbose=True):
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.common.hw import TPU_V5E
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shardings import named
    from repro.models.transformer import gqa_layout
    from repro.configs import get_config

    mesh = make_production_mesh(multi_pod=True)
    devs = np.asarray(mesh.devices)              # [2, 16, 16]
    prefill_mesh = jax.sharding.Mesh(devs[0], ("data", "model"))
    decode_mesh = jax.sharding.Mesh(devs[1], ("data", "model"))

    # prompt-phase program on pod 0
    pcell = steps.build_prefill(arch, prefill_mesh)
    pjit_ = jax.jit(pcell["fn"],
                    in_shardings=named(prefill_mesh, pcell["in_shardings"]),
                    donate_argnums=pcell["donate"])
    p_compiled = pjit_.lower(*pcell["args"]).compile()

    # token-phase program on pod 1
    dcell = steps.build_decode(arch, decode_mesh)
    djit_ = jax.jit(dcell["fn"],
                    in_shardings=named(decode_mesh, dcell["in_shardings"]),
                    donate_argnums=dcell["donate"])
    d_compiled = djit_.lower(*dcell["args"]).compile()

    # KV handoff: per request, the prefill pod ships the full prompt KV to
    # the decode pod (jax.device_put across meshes / ICI+DCN).
    cfg = get_config(arch)
    _, KV_p, _, _, _ = gqa_layout(cfg.n_heads, cfg.n_kv_heads,
                                  prefill_mesh.shape["model"])
    layers = cfg.n_layers if cfg.family != "hybrid" else \
        __import__("repro.models.hybrid", fromlist=["x"]).group_structure(cfg)[0]
    kv_bytes_per_req = 2 * layers * seq * KV_p * cfg.head_dim * 2  # k+v bf16
    # cross-pod links: one ICI/DCN hop; per-chip share of the transfer
    t_handoff = kv_bytes_per_req / TPU_V5E.ici_bw_per_link
    out = dict(
        arch=arch,
        prefill_mem_GiB=p_compiled.memory_analysis().temp_size_in_bytes / 2**30,
        decode_mem_GiB=d_compiled.memory_analysis().temp_size_in_bytes / 2**30,
        kv_handoff_bytes_per_req=kv_bytes_per_req,
        t_handoff_per_req_s=t_handoff,
    )
    if verbose:
        print(f"[splitwise x {arch}] prefill(pod0) + decode(pod1) both "
              f"compiled on their 16x16 submeshes")
        print(f"  KV handoff: {kv_bytes_per_req/2**30:.2f} GiB/request "
              f"-> {t_handoff*1e3:.1f} ms/request over one 50 GB/s link")
        print(f"  (Splitwiser's same-chip mixed batching pays ZERO handoff; "
              f"this is the paper's motivating overhead)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()
    analyze_splitwise(args.arch)


if __name__ == "__main__":
    main()
