"""shard_map islands for the two ops GSPMD cannot partition well:

1. paged attention + KV page writes (data-dependent page scatter/gather:
   under plain GSPMD the partitioner cannot prove block-table locality and
   materializes all-gathers of the page pool — the measured baseline
   pathology in EXPERIMENTS.md §Perf);
2. MoE dispatch (data-dependent scatter): formulated as expert-local
   compute + ONE psum over `model` — the same all-reduce a dense TP MLP
   pays, so EP adds no extra collective phase.

Both wrappers keep the *global* calling convention of the model code; the
bodies run on per-shard local arrays.

Locality invariant: a sequence's pages live on its data shard and block
tables store pool-local indices modulo the per-shard pool size (the
engine's allocator partitions the pool per data shard; `% N_local` maps
global ids to local ones).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax.experimental.shard_map import shard_map
except ImportError:                                    # newer jax
    from jax import shard_map

from repro.models.layers import paged_attention_ref, act_fn
from repro.models.moe import moe_apply
from repro.models.transformer import write_kv_chunk, write_kv_token

# int8 KV machinery now lives in kernels/kv_int8.py (promoted from here);
# re-exported so existing imports (tests/test_int8_kv.py, downstream
# users of the spmd entry point) keep working unchanged.
from repro.kernels.kv_int8 import (  # noqa: F401  (re-export surface)
    int8_chunk_attn, int8_decode_attn, paged_attention_int8, q8_kv,
)


def _dspec(data):
    return data if len(data) > 1 else data[0]


def make_sharded_decode_attn(mesh, *, data=("data",), model="model",
                             shard_batch=True, kv_int8=False):
    """default_decode_attn-shaped write+attend step inside shard_map.

    shard_batch=False replicates the (tiny) decode batch over data —
    pages then shard over their PAGE dim instead (single-sequence long-
    context layout)."""
    d = _dspec(data)
    if shard_batch:
        q_spec = P(d, None, model, None)
        kn_spec = P(d, model, None)
        pg_spec = P(d, None, model, None)
        bt_spec, v_spec = P(d, None), P(d)
    else:
        q_spec = P(None, None, model, None)
        kn_spec = P(None, model, None)
        pg_spec = P(None, None, model, None)      # replicated over data
        bt_spec, v_spec = P(None, None), P(None)

    def local(q, k_new, v_new, kpg, vpg, bt, lens, active, win, *, scale,
              softcap):
        if kv_int8:
            bt_loc = bt % kpg["q"].shape[0]
            return int8_decode_attn(q, k_new, v_new, kpg, vpg, bt_loc, lens,
                                    active, scale=scale, window=win,
                                    attn_softcap=softcap)
        bt_loc = bt % kpg.shape[0]
        kpg, vpg = write_kv_token(kpg, vpg, k_new, v_new, bt_loc, lens, active)
        o = paged_attention_ref(q, kpg, vpg, bt_loc, lens + 1, lens[:, None],
                                scale=scale, window=win, attn_softcap=softcap)
        return o, kpg, vpg

    def fn(q, k_new, v_new, kpg, vpg, bt, lens, active, *, scale, window,
           attn_softcap):
        win = jnp.asarray(window if window is not None else 2**30, jnp.int32)
        body = functools.partial(local, scale=scale, softcap=attn_softcap)
        pspec = {"q": pg_spec, "s": pg_spec} if kv_int8 else pg_spec
        mapped = shard_map(
            body, mesh=mesh,
            in_specs=(q_spec, kn_spec, kn_spec, pspec, pspec, bt_spec,
                      v_spec, v_spec, P()),
            out_specs=(q_spec, pspec, pspec),
            check_rep=False,
        )
        return mapped(q, k_new, v_new, kpg, vpg, bt, lens, active, win)

    return fn


def make_sharded_chunk_attn(mesh, *, data=("data",), model="model",
                            kv_int8=False):
    """default_chunk_attn-shaped step: chunked-prefill write + attend over
    paged history. Streams shard over data (engine pins stream i to data
    shard i*P/n_data)."""
    d = _dspec(data)
    q_spec = P(d, None, model, None)
    kn_spec = P(d, None, model, None)
    pg_spec = P(d, None, model, None)
    bt_spec, v_spec = P(d, None), P(d)

    def local(q, k_new, v_new, kpg, vpg, bt, start, lens, win, *, scale,
              softcap):
        C = q.shape[1]
        q_pos = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        if kv_int8:
            bt_loc = bt % kpg["q"].shape[0]
            return int8_chunk_attn(q, k_new, v_new, kpg, vpg, bt_loc, start,
                                   lens, scale=scale, window=win,
                                   attn_softcap=softcap)
        bt_loc = bt % kpg.shape[0]
        kpg, vpg = write_kv_chunk(kpg, vpg, k_new, v_new, bt_loc, start, lens)
        o = paged_attention_ref(q, kpg, vpg, bt_loc, start + lens, q_pos,
                                scale=scale, window=win, attn_softcap=softcap)
        return o, kpg, vpg

    def fn(q, k_new, v_new, kpg, vpg, bt, start, lens, *, scale, window,
           attn_softcap):
        win = jnp.asarray(window if window is not None else 2**30, jnp.int32)
        body = functools.partial(local, scale=scale, softcap=attn_softcap)
        pspec = {"q": pg_spec, "s": pg_spec} if kv_int8 else pg_spec
        mapped = shard_map(
            body, mesh=mesh,
            in_specs=(q_spec, kn_spec, kn_spec, pspec, pspec, bt_spec,
                      v_spec, v_spec, P()),
            out_specs=(q_spec, pspec, pspec),
            check_rep=False,
        )
        return mapped(q, k_new, v_new, kpg, vpg, bt, start, lens, win)

    return fn


def make_sharded_moe_fn(mesh, cfg, *, tp: int, data=("data",), model="model",
                        flat_f=False, fsdp_gather=False):
    """EP/expert-TP MoE: local dispatch + expert GEMMs + one psum.

    flat_f (the large-model decode scheme, §Perf): expert d_ff is sharded
    over EVERY mesh axis (e.g. 32768/256 = 128 per chip for grok-1) with
    token activations replicated — per-chip expert bytes drop n_data-fold
    and no weight collective exists at all; combine = one [T, D] psum over
    all axes."""
    d = _dspec(data)
    E = cfg.n_experts
    ep = E % tp == 0 and not flat_f
    gate_act = act_fn("silu" if cfg.mlp_act == "silu" else "gelu")
    flat = tuple(data) + (model,)

    if fsdp_gather:
        # training profile for FSDP'd expert weights: keep the data-axis
        # shard INSIDE the island and all-gather here — the autodiff
        # transpose is then a reduce-scatter (not a replicated-grad
        # rematerialization; fixes a 600 GiB/dev peak on grok-1 train).
        dax = data[-1]
        # per-layer shapes: w_gate/w_up [E, D, F] (FSDP on D=dim1),
        # w_down [E, F, D] (FSDP on D=dim2) — mirrors param_pspecs
        if ep:
            w_spec = {"router": P(None, None),
                      "w_gate": P(model, dax, None), "w_up": P(model, dax, None),
                      "w_down": P(model, None, dax)}
        else:
            w_spec = {"router": P(None, None),
                      "w_gate": P(None, dax, model), "w_up": P(None, dax, model),
                      "w_down": P(None, model, dax)}
        x_spec = P(_dspec(data), None)
        psum_axes = (model,)
    elif flat_f:
        w_spec = {"router": P(None, None),
                  "w_gate": P(None, None, flat), "w_up": P(None, None, flat),
                  "w_down": P(None, flat, None)}
        x_spec = P(None, None)          # tokens replicated
        psum_axes = flat
    elif ep:
        w_spec = {"router": P(None, None),
                  "w_gate": P(model, None, None), "w_up": P(model, None, None),
                  "w_down": P(model, None, None)}
        x_spec = P(d, None)
        psum_axes = (model,)
    else:
        w_spec = {"router": P(None, None),
                  "w_gate": P(None, None, model), "w_up": P(None, None, model),
                  "w_down": P(None, model, None)}
        x_spec = P(d, None)
        psum_axes = (model,)

    def local(lp, x2d):
        if fsdp_gather:
            dax = data[-1]
            lp = dict(lp)
            for kname in ("w_gate", "w_up"):
                lp[kname] = jax.lax.all_gather(lp[kname], dax, axis=1,
                                               tiled=True)
            lp["w_down"] = jax.lax.all_gather(lp["w_down"], dax, axis=2,
                                              tiled=True)
        offset = jax.lax.axis_index(model) * (E // tp) if ep else 0
        y, aux = moe_apply(lp, x2d, n_experts=E, top_k=cfg.top_k,
                           act=gate_act, expert_offset=offset,
                           capacity_factor=cfg.moe_capacity_factor)
        y = jax.lax.psum(y, psum_axes)
        aux = jax.lax.pmean(aux, psum_axes)
        return y, aux

    mapped = shard_map(
        local, mesh=mesh,
        in_specs=(w_spec, x_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    return lambda lp, x2d: mapped(lp, x2d)
