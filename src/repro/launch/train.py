"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 --batch 8 --seq 128 [--reduced]

On this CPU container use --reduced (full configs are exercised via the
dry-run). On a real TPU pod the same entry point runs the production mesh:
    python -m repro.launch.train --arch qwen3-0.6b --mesh single ...
"""
from __future__ import annotations

import argparse
import time


from repro.configs import TrainConfig, get_config
from repro.data import make_train_data_fn
from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) config on CPU")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--int8-moments", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(args.arch, cfg, FAMILY_MODULE[cfg.family],
                  CACHE_KIND[cfg.family])
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                       total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, microbatch=args.microbatch,
                       int8_moments=args.int8_moments, remat=True)
    data_fn = make_train_data_fn(cfg, tcfg)
    trainer = Trainer(model, tcfg, data_fn)
    print(f"arch={args.arch} ({cfg.name}) family={cfg.family} "
          f"start_step={trainer.start_step}")
    t0 = time.time()
    hist = trainer.run()
    dt = time.time() - t0
    for step, loss in hist:
        print(f"step {step:5d} loss {loss:.4f}")
    n_tok = args.steps * args.batch * args.seq
    print(f"done: {dt:.1f}s, {n_tok/dt:.0f} tok/s")


if __name__ == "__main__":
    main()
