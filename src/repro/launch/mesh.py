"""Production meshes.

Single pod: 16x16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the pod axis is
pure data parallelism for training (cross-pod gradient all-reduce over
DCN/ICI) and replica/phase-pool parallelism for serving.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many (possibly forced-host) devices exist."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
