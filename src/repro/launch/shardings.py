"""PartitionSpecs for every parameter/cache/input tensor, per architecture.

Rules are path+shape driven and cover all six families. Two profiles:
  * train: TP over `model`, FSDP (ZeRO) over the data axis for the second
    weight dim + optimizer state.
  * serve: TP over `model`; FSDP only for archs whose weights exceed
    per-chip HBM at TP=16 (grok-1) — ZeRO-3-style per-layer gather.
Axes that don't divide a dim are dropped (with the padding layouts in the
models, this only happens for genuinely tiny tensors).
"""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import PartitionSpec as P


def _parts(path):
    return [str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path]


def param_pspecs(shapes_tree, cfg, *, tp: int, fsdp_size: int = 1,
                 model="model", fsdp=None):
    """Pytree of PartitionSpec matching the params tree."""
    M = model if tp > 1 else None      # tp=1: pure-FSDP scheme, no TP axes
    F = fsdp if (fsdp and fsdp_size > 1) else None

    def spec_for(path, leaf):
        parts = _parts(path)
        name = parts[-1]
        shape = leaf.shape
        nd = len(shape)
        s: list = [None] * nd
        in_tmix = "tmix" in parts
        in_cmix = "cmix" in parts
        in_moe = "moe" in parts

        if name in ("embed", "head"):
            s = [M, F]
        elif in_tmix:
            if name in ("wr", "wk", "wv", "wg"):
                s[-2], s[-1] = F, M
            elif name == "wo":
                s[-2], s[-1] = M, F
            elif name == "u":
                s[-2] = M
            elif name in ("ln_x", "w0", "w_b"):
                s[-1] = M
            elif name == "w_a":
                s[-2] = F
        elif in_cmix:
            if name == "wk":
                s[-2], s[-1] = F, M
            elif name == "wv":
                s[-2], s[-1] = M, F
            elif name == "wr":
                s[-2] = F
        elif in_moe:
            E = shape[1] if nd == 4 else 0
            if name == "router":
                s[-2] = F
            elif name in ("w_gate", "w_up"):
                if E % tp == 0:
                    s[1], s[2] = M, F          # EP: experts over model
                else:
                    s[2], s[3] = F, M          # expert-TP: d_ff over model
            elif name == "w_down":
                if E % tp == 0:
                    s[1], s[3] = M, F
                else:
                    s[2], s[3] = M, F
        elif name == "wq":                     # [.., D, H_p, hd]
            s[-2], s[-3] = M, F
        elif name in ("wk", "wv"):             # [.., D, KV, hd]
            if shape[-2] % tp == 0:
                s[-2] = M
            s[-3] = F
        elif name == "wo":                     # [.., H_p, hd, D]
            s[-3], s[-1] = M, F
        elif name in ("w_gate", "w_up", "w_in"):   # [.., D, F]
            s[-2], s[-1] = F, M
        elif name in ("w_down", "w_out"):      # [.., F, D]
            s[-2], s[-1] = M, F
        elif name in ("wz", "wx"):             # mamba [., D, d_in]
            s[-2], s[-1] = F, M
        elif name in ("wB", "wC", "wdt"):
            s[-2] = F
        elif name == "out_proj":               # mamba [., d_in, D]
            s[-2], s[-1] = M, F
        elif name in ("conv_x", "conv_b_x", "norm",
                      "qb", "kb", "vb"):       # + zamba lora [13, r, H*hd]
            s[-1] = M
        elif name in ("qa", "ka", "va"):       # [13, 2D, r]
            s[-2] = F
        # everything else (norm scales, mixes, small biases) replicated

        # drop axes that don't divide
        for i, ax in enumerate(s):
            if ax is None:
                continue
            size = tp if ax == M else fsdp_size
            if shape[i] % size != 0:
                s[i] = None
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec_for, shapes_tree)


def opt_pspecs(param_specs):
    """Optimizer state specs: moments mirror params; count replicated."""
    return {"mu": param_specs, "nu": param_specs, "count": P()}


def named(mesh, tree_of_pspecs):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
