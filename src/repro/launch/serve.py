"""Serving launcher — drive the Splitwiser engine on a synthetic workload.

    PYTHONPATH=src python -m repro.launch.serve --arch opt-125m --reduced \
        --mode splitwiser_mps --n-requests 16 --input-tokens 64 \
        --output-tokens 16

Modes: sequential | splitwiser | splitwiser_mps (paper arms; see
core/engine.py).  Sampling knobs (--temperature/--top-k/--top-p/--seed)
apply per request via ``SamplingParams``; ``--arrival-rate R`` switches
to an open-loop replay with Poisson arrivals at R requests per virtual
second.  Prints the paper's metrics (E2E, TTFT, TBT, throughput, KV
usage).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import ServeConfig, get_config
from repro.core.engine import Engine, Request
from repro.core.sampler import SamplingParams
from repro.data import report_tokens
from repro.models.registry import CACHE_KIND, FAMILY_MODULE, Model


def build_engine(arch, mode, *, reduced=True, max_batch=8, page_size=16,
                 n_pages=512, n_streams=2, prefill_chunk=64, seed=0,
                 max_pages_per_seq=64):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Model(arch, cfg, FAMILY_MODULE[cfg.family], CACHE_KIND[cfg.family])
    params = model.init(jax.random.PRNGKey(seed))
    serve = ServeConfig(mode=mode, max_batch=max_batch, page_size=page_size,
                        n_pages=n_pages, n_streams=n_streams,
                        prefill_chunk=prefill_chunk,
                        max_pages_per_seq=max_pages_per_seq)
    return Engine(model, params, serve), cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--mode", default="splitwiser_mps",
                    choices=["sequential", "splitwiser", "splitwiser_mps"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--input-tokens", type=int, default=64)
    ap.add_argument("--output-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--n-streams", type=int, default=2)
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request sampling seed")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrivals at this many req/s "
                         "(0 = closed loop, all requests at t=0)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    engine, cfg = build_engine(
        args.arch, args.mode, reduced=args.reduced, max_batch=args.max_batch,
        n_streams=args.n_streams, prefill_chunk=args.prefill_chunk,
        n_pages=max(512, args.n_requests *
                    (args.input_tokens + args.output_tokens) // 16 + 64),
        max_pages_per_seq=(args.input_tokens + args.output_tokens) // 16 + 2)
    prompts = report_tokens(args.n_requests, args.input_tokens,
                            cfg.vocab_size)
    sampling = SamplingParams(max_new_tokens=args.output_tokens,
                              temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed)
    open_loop = args.arrival_rate > 0
    arrivals = (np.cumsum(np.random.default_rng(0).exponential(
        1.0 / args.arrival_rate, size=args.n_requests))
        if open_loop else [None] * args.n_requests)
    reqs = [Request(rid=i, prompt=p, sampling=sampling, arrival=arrivals[i])
            for i, p in enumerate(prompts)]
    metrics = engine.run(reqs, open_loop=open_loop)
    outputs = engine.poll()
    s = metrics.summary()
    if args.json:
        s["finish_reason_by_rid"] = {o.rid: o.finish_reason for o in outputs}
        print(json.dumps(s, default=str))
    else:
        print(f"mode={args.mode} done={s['n_done']}/{args.n_requests} "
              f"steps={s['n_steps']} wall={s['wall_s']:.2f}s "
              f"open_loop={open_loop}")
        print(f"throughput {s['throughput_tok_s']:.1f} tok/s | "
              f"TTFT mean {s['ttft']['mean']:.3f}s | "
              f"TBT mean {(s['tbt']['mean'] or 0):.4f}s | "
              f"E2E mean {s['e2e']['mean']:.3f}s")
        print(f"KV usage peak {s['kv_usage_peak']:.1%} "
              f"mean {s['kv_usage_mean']:.1%} | "
              f"finish_reasons {s['finish_reasons']}")


if __name__ == "__main__":
    main()
