import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape decode_32k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl

The XLA_FLAGS line above MUST execute before any jax import (jax locks the
device count on first init); 512 host devices back both the 16x16 and the
2x16x16 meshes. ShapeDtypeStruct inputs -> .lower() never allocates.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.registry import ASSIGNED, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.steps import build_cell

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "mixed_32k"]
# mixed_32k is the extra paper-technique cell, lowered for the two MoE
# archs + qwen3 (the Splitwiser fused step at pod scale)
MIXED_ARCHS = {"qwen3-0.6b", "olmoe-1b-7b"}


def run_cell(arch, shape, mesh_name, *, verbose=True):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    cell, why = build_cell(arch, shape, mesh)
    if cell is None:
        return dict(arch=arch, shape=shape, mesh=mesh_name, status="skipped",
                    reason=why)
    t0 = time.time()
    from repro.launch.shardings import named
    jitted = jax.jit(cell["fn"], in_shardings=named(mesh, cell["in_shardings"]),
                     donate_argnums=cell["donate"])
    lowered = jitted.lower(*cell["args"])
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cfg = get_config(arch)
    jaxpr = jax.make_jaxpr(cell["fn"])(*cell["args"])
    rl = analyze(compiled, arch=arch, shape=shape, mesh_name=mesh_name,
                 n_devices=mesh.size, cfg=cfg, jaxpr=jaxpr,
                 flop_divisor=cell.get("flop_divisor"))
    row = rl.row()
    row.update(status="ok", note=cell["note"], t_lower_s=round(t_lower, 1),
               t_compile_s=round(t_compile, 1))
    mem = compiled.memory_analysis()
    row["memory_analysis"] = str(mem)
    if verbose:
        print(f"[{arch} x {shape} x {mesh_name}] OK "
              f"mem/dev={row['peak_mem_GiB']:.2f}GiB "
              f"t_c={row['t_compute_s']:.3e}s t_m={row['t_memory_s']:.3e}s "
              f"t_coll={row['t_collective_s']:.3e}s -> {row['bottleneck']}")
        print(f"  memory_analysis: {mem}")
        print(f"  cost: flops/dev={row['flops_per_dev']:.3e} "
              f"bytes/dev={row['bytes_per_dev']:.3e} "
              f"coll/dev={row['coll_bytes_per_dev']:.3e} "
              f"useful_ratio={row['useful_ratio']:.3f}")
    return row


def cells_for(arch):
    out = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    if arch in MIXED_ARCHS:
        out.append("mixed_32k")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=SHAPE_ORDER)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s, m) for a in ASSIGNED for s in cells_for(a)
                 for m in meshes]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape are required unless --all is set")
        cells = [(args.arch, args.shape, m) for m in meshes]

    rows, failures = [], 0
    for arch, shape, mesh_name in cells:
        try:
            row = run_cell(arch, shape, mesh_name)
        except Exception as e:
            traceback.print_exc()
            row = dict(arch=arch, shape=shape, mesh=mesh_name,
                       status="FAIL", error=f"{type(e).__name__}: {e}")
            failures += 1
        rows.append(row)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(row, default=str) + "\n")
    ok = sum(r["status"] == "ok" for r in rows)
    sk = sum(r["status"] == "skipped" for r in rows)
    print(f"\n=== dry-run: {ok} ok / {sk} skipped / {failures} FAILED "
          f"of {len(rows)} cells ===")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
