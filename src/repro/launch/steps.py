"""Dry-run cell builders: (architecture x input-shape) -> jit-able step.

Each builder returns a dict:
  fn            — python callable
  args          — tuple of ShapeDtypeStruct pytrees (abstract, no alloc)
  in_shardings  — matching tuple of PartitionSpec pytrees
  donate        — argnums to donate (page pools / train state)
  note          — human-readable cell description

Shapes (assignment): train_4k / prefill_32k / decode_32k / long_500k,
plus mixed_32k — the paper-representative Splitwiser fused step (16
prompt streams x 2048-token chunks + 128 decode slots @32k).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import TrainConfig, get_config
from repro.launch import spmd
from repro.launch.shardings import param_pspecs
from repro.models import encdec, hybrid, rwkv
from repro.models import transformer as T
from repro.models.registry import Model, FAMILY_MODULE, CACHE_KIND
from repro.models.sharding import Policy, make_rules
from repro.train.trainer import init_state, make_train_step

PAGE = 64

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
    "mixed_32k": dict(kind="mixed", seq=32768, batch=128, chunk=2048,
                      streams=16),
}

# archs whose weights exceed one chip's HBM share at TP=16 -> ZeRO-3-style
# data-axis weight sharding even for serving
SERVE_FSDP = {"grok-1-314b"}

F32, BF16, I32 = jnp.float32, jnp.bfloat16, jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def cell_supported(cfg, shape_name: str):
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention KV residency at 524288 ctx; "
                       "needs context-streaming attention — skipped")
    if shape_name == "mixed_32k" and cfg.family not in ("dense", "moe", "vlm"):
        return False, "mixed fused step is transformer-family (paper cell)"
    return True, ""


def get_model(arch):
    cfg = get_config(arch)
    return Model(arch, cfg, FAMILY_MODULE[cfg.family], CACHE_KIND[cfg.family])


def _axes(mesh):
    multi = "pod" in mesh.axis_names
    da = ("pod", "data") if multi else ("data",)
    n_data = math.prod(mesh.shape[a] for a in da)
    return da, n_data, mesh.shape["model"]


def _dspec(da):
    return da if len(da) > 1 else da[0]


def _policy(mesh, da, fsdp: bool):
    return Policy(make_rules(da, "model", fsdp=fsdp), mesh)


# ------------------------------------------------------------------ train --
def build_train(arch, mesh, scheme="tp"):
    '''scheme: "tp" (baseline: TP over model + ZeRO over data),
    "fsdp" (pure 256-way DP + ZeRO-3 over BOTH axes — §Perf optimization
    for small archs whose TP activation all-reduces dominate),
    either with "+vtiled" appended for the fused vocab-tiled CE loss.'''
    model = get_model(arch)
    cfg = model.cfg
    da, n_data, tp = _axes(mesh)
    fsdp_only = scheme.startswith("fsdp")
    vtiled = scheme.endswith("vtiled")
    sh = SHAPES["train_4k"]
    tcfg = TrainConfig(global_batch=sh["batch"], seq_len=sh["seq"], remat=True,
                       int8_moments=(arch in SERVE_FSDP),
                       loss_impl="vtiled" if vtiled else "chunked")
    if fsdp_only:
        tp = 1
        flat = tuple(da) + ("model",)
        rules = make_rules((flat,) if False else flat, "model", fsdp=True)
        # batch + fsdp over ALL axes; no tensor parallelism
        rules = dict(rules)
        for k in ("batch", "tokens", "pages", "fsdp"):
            rules[k] = flat
        for k in ("heads", "kv_heads", "ff", "vocab", "experts"):
            rules[k] = None
        policy = Policy(rules, mesh)
    else:
        policy = _policy(mesh, da, fsdp=True)
    moe_fn = (spmd.make_sharded_moe_fn(mesh, cfg, tp=tp, data=da,
                                       fsdp_gather=True)
              if cfg.is_moe and not fsdp_only else None)
    step = make_train_step(model, tcfg, tp=tp, policy=policy, moe_fn=moe_fn)

    state_shapes = jax.eval_shape(
        lambda: init_state(model, jax.random.PRNGKey(0), tcfg, BF16, tp=tp))
    if fsdp_only:
        flat = tuple(da) + ("model",)
        p_specs = param_pspecs(state_shapes["params"], cfg, tp=1,
                               fsdp_size=n_data * mesh.shape["model"],
                               fsdp=flat)
        o_specs = _opt_specs(state_shapes["opt"], p_specs, da, tp)
        state_specs = {"params": p_specs, "opt": o_specs}
    else:
        p_specs = param_pspecs(state_shapes["params"], cfg, tp=tp,
                               fsdp_size=mesh.shape["data"], fsdp="data")
        o_specs = _opt_specs(state_shapes["opt"], p_specs, da, tp)
        state_specs = {"params": p_specs, "opt": o_specs}

    B, S = sh["batch"], sh["seq"]
    d = (tuple(da) + ("model",)) if fsdp_only else _dspec(da)
    batch_shapes = {"tokens": sds((B, _text_len(cfg, S)), I32),
                    "labels": sds((B, _text_len(cfg, S)), I32)}
    batch_specs = {"tokens": P(d, None), "labels": P(d, None)}
    if cfg.family == "encdec":
        batch_shapes["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), BF16)
        batch_specs["frames"] = P(d, None, None)
    if cfg.family == "vlm":
        batch_shapes["patches"] = sds((B, cfg.n_vision_patches, cfg.d_vision), BF16)
        batch_specs["patches"] = P(d, None, None)
    return dict(fn=step, args=(state_shapes, batch_shapes),
                in_shardings=(state_specs, batch_specs), donate=(0,),
                note=f"train_step B={B} S={S} remat scheme={scheme} "
                     f"int8_mom={tcfg.int8_moments}")


def _text_len(cfg, seq):
    """vlm text tokens = seq - vision prefix so total context == seq."""
    return seq - cfg.n_vision_patches if cfg.family == "vlm" else seq


def _opt_specs(opt_shapes, p_specs, da, tp):
    """Moment specs mirror the parameter specs. Q8 moments are
    shape-preserving (codes = param shape; scales = param shape with the
    last dim blocked), so they inherit the param spec with per-dim
    divisibility re-checked."""
    from repro.launch.shardings import _parts
    spec_map = {}
    def record(path, leaf):
        spec_map["/".join(_parts(path))] = leaf
        return leaf
    jax.tree_util.tree_map_with_path(record, p_specs,
                                     is_leaf=lambda x: isinstance(x, P))

    def fit(spec, shape):
        s = list(spec) + [None] * (len(shape) - len(spec))
        for i, ax in enumerate(s[: len(shape)]):
            if ax is None:
                continue
            size = 16 if not isinstance(ax, tuple) else 16 * len(ax)
            if shape[i] % size != 0:
                s[i] = None
        return P(*s[: len(shape)])

    def f(path, leaf):
        parts = _parts(path)
        if parts[0] == "count":
            return P()
        # Q8 moments flatten as NamedTuple attribute keys ('q'/'scale')
        # or positional digits depending on jax version — strip either
        last = parts[-1]
        strip = last.isdigit() or last in ("q", "scale", "0", "1")
        key = "/".join(parts[1:-1] if strip else parts[1:])
        base = spec_map.get(key, P())
        return fit(base, leaf.shape)

    return jax.tree_util.tree_map_with_path(f, opt_shapes)


# ---------------------------------------------------------------- prefill --
def build_prefill(arch, mesh, shape_name="prefill_32k"):
    model = get_model(arch)
    cfg = model.cfg
    da, n_data, tp = _axes(mesh)
    d = _dspec(da)
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    fsdp = "data" if arch in SERVE_FSDP else None
    policy = _policy(mesh, da, fsdp=False)
    moe_fn = (spmd.make_sharded_moe_fn(mesh, cfg, tp=tp, data=da)
              if cfg.is_moe else None)

    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                                      BF16, tp=tp))
    p_specs = param_pspecs(params_shapes, cfg, tp=tp,
                           fsdp_size=mesh.shape["data"], fsdp=fsdp)

    if cfg.family in ("dense", "moe"):
        def fn(params, tokens):
            return T.prefill(params, cfg, tokens, tp=tp, policy=policy,
                             moe_fn=moe_fn)
        args = (params_shapes, sds((B, S), I32))
        in_sh = (p_specs, P(d, None))
    elif cfg.family == "vlm":
        def fn(params, tokens, patches):
            return T.prefill(params, cfg, tokens, patches=patches, tp=tp,
                             policy=policy, moe_fn=moe_fn)
        args = (params_shapes, sds((B, _text_len(cfg, S)), I32),
                sds((B, cfg.n_vision_patches, cfg.d_vision), BF16))
        in_sh = (p_specs, P(d, None), P(d, None, None))
    elif cfg.family == "encdec":
        def fn(params, frames, tokens):
            return encdec.prefill(params, cfg, frames, tokens, tp=tp,
                                  policy=policy)
        args = (params_shapes, sds((B, cfg.encoder_seq, cfg.d_model), BF16),
                sds((B, S), I32))
        in_sh = (p_specs, P(d, None, None), P(d, None))
    elif cfg.family == "hybrid":
        def fn(params, tokens):
            return hybrid.prefill(params, cfg, tokens, tp=tp, policy=policy)
        args = (params_shapes, sds((B, S), I32))
        in_sh = (p_specs, P(d, None))
    else:  # ssm
        def fn(params, tokens):
            return rwkv.prefill(params, cfg, tokens, tp=tp, policy=policy,
                                chunk=64)
        args = (params_shapes, sds((B, S), I32))
        in_sh = (p_specs, P(d, None))
    return dict(fn=fn, args=args, in_shardings=in_sh, donate=(),
                note=f"prefill B={B} S={S}")


# ----------------------------------------------------------------- decode --
def _page_pool_shapes(cfg, tp, n_seqs, seq, n_data, n_layers=None,
                      extra_seqs=0):
    """(pages shape [L,N,ps,KV_p,hd], Pmax). N is data-divisible and
    includes per-shard trash pages."""
    _, KV_p, _, _, _ = T.gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    L = n_layers if n_layers is not None else cfg.n_layers
    per_seq = seq // PAGE + 1
    n_raw = (n_seqs + extra_seqs) * per_seq + n_data
    N = -(-n_raw // n_data) * n_data
    Pmax = per_seq
    return (L, N, PAGE, KV_p, cfg.head_dim), Pmax


def build_decode(arch, mesh, shape_name="decode_32k", scheme="zero3"):
    """scheme "zero3" (baseline): batch sharded over data; with FSDP'd
    weights (grok) GSPMD must all-gather each layer's weights per token
    step — measured collective-bound. scheme "2d": GEMM activations
    replicated over data (weights stay 2D-sharded, contraction partials
    psum'd; attention/pages stay data-sharded) — the §Perf fix."""
    model = get_model(arch)
    cfg = model.cfg
    da, n_data, tp = _axes(mesh)
    d = _dspec(da)
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    shard_batch = (B % n_data == 0) and scheme != "2d"
    flat_f = scheme == "2d"
    fsdp = "data" if (arch in SERVE_FSDP and scheme != "2d") else None
    policy = _policy(mesh, da, fsdp=False) if shard_batch else None

    def _2d_overrides(p_specs):
        """Flat (data x model) sharding of the OUTPUT/F dims of every big
        weight: nothing big sits on a contraction dim, so GSPMD cannot
        choose weight all-gathers; activations stay replicated and the
        per-layer collective is one tiny [B, D] psum."""
        flat = tuple(da) + ("model",)
        from repro.launch.shardings import _parts
        def fix(path, spec_leaf):
            parts = _parts(path)
            name = parts[-1]
            if name in ("w_gate", "w_up") and "moe" in parts:
                return P(None, None, None, flat)
            if name == "w_down" and "moe" in parts:
                return P(None, None, flat, None)
            return spec_leaf
        return jax.tree_util.tree_map_with_path(
            fix, p_specs, is_leaf=lambda x: isinstance(x, P))
    moe_fn = (spmd.make_sharded_moe_fn(mesh, cfg, tp=tp, data=da,
                                       flat_f=flat_f)
              if cfg.is_moe else None)
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                                      BF16, tp=tp))
    p_specs = param_pspecs(params_shapes, cfg, tp=tp,
                           fsdp_size=mesh.shape["data"], fsdp=fsdp)
    if scheme == "2d":
        p_specs = _2d_overrides(p_specs)
    bspec = P(d) if shard_batch else P()
    bspec2 = P(d, None) if shard_batch else P(None, None)

    if cfg.family == "ssm":                   # rwkv: state cache only
        state_shapes = jax.eval_shape(
            lambda: rwkv.init_state(cfg, B, BF16))
        st_specs = jax.tree.map(
            lambda x: P(None, d if shard_batch else None, "model", None)
            if x.ndim == 4 else P(None, d if shard_batch else None, "model"),
            state_shapes)
        # x_tm/x_cm [L,B,D]: D sharded over model? keep replicated D
        st_specs = {
            "x_tm": P(None, d if shard_batch else None, None),
            "x_cm": P(None, d if shard_batch else None, None),
            "S": P(None, d if shard_batch else None, "model", None),
        }
        def fn(params, tokens, state):
            return rwkv.decode(params, cfg, tokens, state, policy=policy)
        return dict(fn=fn, args=(params_shapes, sds((B,), I32), state_shapes),
                    in_shardings=(p_specs, bspec, st_specs), donate=(2,),
                    flop_divisor=None if shard_batch else tp,
                    note=f"decode(state) B={B} ctx={S}")

    if cfg.family == "hybrid":
        n_attn, n_mamba, _, _, _ = hybrid.group_structure(cfg)
        pg_shape, Pmax = _page_pool_shapes(cfg, tp, B, S, n_data,
                                           n_layers=n_attn)
        conv_sh, ssm_sh = None, None
        cs, ss = __import__("repro.models.ssm", fromlist=["x"]).mamba2_state_shapes(cfg, B)
        conv_shapes = {k: sds((n_mamba,) + v, BF16) for k, v in cs.items()}
        ssm_shapes = sds((n_mamba,) + ss, F32)
        db = d if shard_batch else None
        conv_specs = {"x": P(None, db, None, "model"),
                      "B": P(None, db, None, None),
                      "C": P(None, db, None, None)}
        ssm_specs = P(None, db, "model", None, None)
        pg_spec = (P(None, d, None, "model", None) if shard_batch
                   else P(None, None, None, "model", None))
        attn = spmd.make_sharded_decode_attn(mesh, data=da, model="model",
                                             shard_batch=shard_batch)
        def fn(params, tokens, conv, ssm_st, kpg, vpg, bt, lens):
            return hybrid.decode(params, cfg, tokens, conv, ssm_st, kpg, vpg,
                                 bt, lens, attn_fn=attn, tp=tp, policy=policy)
        args = (params_shapes, sds((B,), I32), conv_shapes, ssm_shapes,
                sds(pg_shape, BF16), sds(pg_shape, BF16),
                sds((B, Pmax), I32), sds((B,), I32))
        in_sh = (p_specs, bspec, conv_specs, ssm_specs, pg_spec, pg_spec,
                 bspec2, bspec)
        return dict(fn=fn, args=args, in_shardings=in_sh, donate=(2, 3, 4, 5),
                    flop_divisor=None if shard_batch else tp,
                    note=f"decode(hybrid) B={B} ctx={S} attn_layers={n_attn}")

    if cfg.family == "encdec":
        pg_shape, Pmax = _page_pool_shapes(cfg, tp, B, S, n_data)
        _, KV_p, _, _, _ = T.gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
        cross_shape = (cfg.n_layers, B, cfg.encoder_seq, KV_p, cfg.head_dim)
        pg_spec = P(None, d, None, "model", None)
        cross_spec = P(None, d, None, "model", None)
        attn = spmd.make_sharded_decode_attn(mesh, data=da, model="model")
        def fn(params, tokens, kpg, vpg, xk, xv, bt, lens):
            return encdec.decode(params, cfg, tokens, kpg, vpg, xk, xv, bt,
                                 lens, attn_fn=attn, tp=tp, policy=policy)
        args = (params_shapes, sds((B,), I32), sds(pg_shape, BF16),
                sds(pg_shape, BF16), sds(cross_shape, BF16),
                sds(cross_shape, BF16), sds((B, Pmax), I32), sds((B,), I32))
        in_sh = (p_specs, bspec, pg_spec, pg_spec, cross_spec, cross_spec,
                 bspec2, bspec)
        return dict(fn=fn, args=args, in_shardings=in_sh, donate=(2, 3),
                    note=f"decode(encdec) B={B} ctx={S}")

    # transformer family
    pg_shape, Pmax = _page_pool_shapes(cfg, tp, B, S, n_data)
    pages_data_sharded = shard_batch or scheme == "2d"
    pg_spec = (P(None, d, None, "model", None) if pages_data_sharded
               else P(None, None, None, "model", None))
    attn = spmd.make_sharded_decode_attn(
        mesh, data=da, model="model", shard_batch=pages_data_sharded)
    def fn(params, tokens, kpg, vpg, bt, lens):
        return T.decode(params, cfg, tokens, kpg, vpg, bt, lens,
                        attn_fn=attn, tp=tp, policy=policy, moe_fn=moe_fn)
    args = (params_shapes, sds((B,), I32), sds(pg_shape, BF16),
            sds(pg_shape, BF16), sds((B, Pmax), I32), sds((B,), I32))
    in_sh = (p_specs, bspec, pg_spec, pg_spec, bspec2, bspec)
    # 2d scheme: GEMMs are replicated over data (outer_mult), islands exact
    return dict(fn=fn, args=args, in_shardings=in_sh, donate=(2, 3),
                flop_divisor=None if (shard_batch or flat_f) else tp,
                outer_mult=n_data if flat_f else 1,
                note=f"decode B={B} ctx={S} pool={pg_shape} scheme={scheme}")


# ------------------------------------------------------------------ mixed --
def build_mixed(arch, mesh, shape_name="mixed_32k", scheme="baseline"):
    """The paper-technique cell: fused chunked-prefill + decode.
    scheme "kv8": int8-quantized KV pages (§Perf, halves KV traffic)."""
    model = get_model(arch)
    cfg = model.cfg
    da, n_data, tp = _axes(mesh)
    d = _dspec(da)
    sh = SHAPES[shape_name]
    B, S, C = sh["batch"], sh["seq"], sh["chunk"]
    # one (or more) prompt streams per data shard — the paper's #processes
    # knob scaled to the mesh
    Pstr = max(sh["streams"], n_data)
    Pstr = -(-Pstr // n_data) * n_data
    policy = _policy(mesh, da, fsdp=False)
    moe_fn = (spmd.make_sharded_moe_fn(mesh, cfg, tp=tp, data=da)
              if cfg.is_moe else None)
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                                      BF16, tp=tp))
    p_specs = param_pspecs(params_shapes, cfg, tp=tp,
                           fsdp_size=mesh.shape["data"],
                           fsdp="data" if arch in SERVE_FSDP else None)
    pg_shape, Pmax = _page_pool_shapes(cfg, tp, B, S, n_data,
                                       extra_seqs=Pstr)
    pg_spec = P(None, d, None, "model", None)
    kv8 = scheme == "kv8"
    attn = {
        "decode": spmd.make_sharded_decode_attn(mesh, data=da, model="model",
                                                kv_int8=kv8),
        "chunk": spmd.make_sharded_chunk_attn(mesh, data=da, model="model",
                                              kv_int8=kv8),
    }

    def fn(params, mb, kpg, vpg):
        return T.mixed(params, cfg, mb, kpg, vpg, attn_fn=attn, tp=tp,
                       policy=policy, moe_fn=moe_fn)

    mb_shapes = dict(
        p_tokens=sds((Pstr, C), I32), p_table=sds((Pstr, Pmax), I32),
        p_start=sds((Pstr,), I32), p_lens=sds((Pstr,), I32),
        d_tokens=sds((B,), I32), d_table=sds((B, Pmax), I32),
        d_lens=sds((B,), I32), d_active=sds((B,), jnp.bool_),
    )
    mb_specs = dict(
        p_tokens=P(d, None), p_table=P(d, None), p_start=P(d), p_lens=P(d),
        d_tokens=P(d), d_table=P(d, None), d_lens=P(d), d_active=P(d),
    )
    if kv8:
        sc_shape = pg_shape[:-1] + (1,)
        pg_arg = {"q": sds(pg_shape, jnp.int8), "s": sds(sc_shape, F32)}
        pg_sp = {"q": pg_spec, "s": pg_spec}
        args = (params_shapes, mb_shapes, pg_arg, dict(pg_arg))
        in_sh = (p_specs, mb_specs, pg_sp, pg_sp)
    else:
        args = (params_shapes, mb_shapes, sds(pg_shape, BF16),
                sds(pg_shape, BF16))
        in_sh = (p_specs, mb_specs, pg_spec, pg_spec)
    return dict(fn=fn, args=args, in_shardings=in_sh, donate=(2, 3),
                note=f"mixed(Splitwiser) streams={Pstr}x{C} + decode B={B} "
                     f"@ctx={S} scheme={scheme}")


def build_cell(arch, shape_name, mesh):
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, shape_name)
    if not ok:
        return None, why
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        return build_train(arch, mesh), ""
    if kind == "prefill":
        return build_prefill(arch, mesh, shape_name), ""
    if kind == "decode":
        return build_decode(arch, mesh, shape_name), ""
    if kind == "mixed":
        return build_mixed(arch, mesh, shape_name), ""
    raise ValueError(kind)
