"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

cost_analysis() on the SPMD-partitioned module reports per-partition
flops/bytes (verified empirically in tests/test_dryrun_small.py) -> we
multiply by n_devices for globals and divide back for the terms.
collective_bytes = sum of OPERAND bytes over every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
partitioned HLO (per-chip injected bytes; ring-algorithm factors are NOT
applied — noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from repro.common.hw import TPU_V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\(?)([a-z0-9]+\[[0-9,]*\])")
_COLL_RE = re.compile(
    r"=\s*(?:\(.*?\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from partitioned HLO text."""
    # first pass: instruction name -> result bytes
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name = m.group(1).lstrip("%")
            # full result type may be a tuple; grab all shapes on the lhs
            lhs = line.split("=", 1)[1]
            # operand list starts at the op name; take text up to the op call
            sizes[name] = _shape_bytes(line.split("=", 1)[1].split("(", 1)[0])
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start)?\(", line)
        if not m or "-done" in line.split("=")[-1][:40]:
            continue
        kind = m.group(1)
        args = line[m.end():line.rfind(")")]
        # operands are %name or name tokens before any attribute
        arg_part = args.split("),")[0] if ")," in args else args
        ops = re.findall(r"%?([\w.\-]+)", arg_part.split(", channel_id")[0])
        b = sum(sizes.get(o, 0) for o in ops if o in sizes)
        if b == 0:
            # fall back: result bytes of this line
            mm = _DEF_RE.match(line)
            if mm:
                b = _shape_bytes(line.split("=", 1)[1].split("(", 1)[0])
        out[kind] = out.get(kind, 0) + b
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: Dict[str, int]
    peak_mem_per_dev: float
    model_flops: float = 0.0

    @property
    def t_compute(self):
        return self.flops_per_dev / TPU_V5E.peak_flops_bf16

    @property
    def t_memory(self):
        return self.bytes_per_dev / TPU_V5E.hbm_bw

    @property
    def t_collective(self):
        return self.coll_bytes_per_dev / TPU_V5E.ici_bw_per_link

    @property
    def bottleneck(self):
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_ratio(self):
        total = self.flops_per_dev * self.n_devices
        return self.model_flops / total if total else 0.0

    def row(self):
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh,
            t_compute_s=self.t_compute, t_memory_s=self.t_memory,
            t_collective_s=self.t_collective, bottleneck=self.bottleneck,
            flops_per_dev=self.flops_per_dev, bytes_per_dev=self.bytes_per_dev,
            coll_bytes_per_dev=self.coll_bytes_per_dev,
            peak_mem_GiB=self.peak_mem_per_dev / 2**30,
            model_flops=self.model_flops,
            useful_ratio=self.useful_flops_ratio,
            coll_breakdown=self.coll_breakdown,
        )


def model_flops_estimate(cfg, shape_name: str) -> float:
    """MODEL_FLOPS: 6·N·D train / 2·N_active·D per forward token (dense
    counting; attention excluded by convention)."""
    from repro.launch.steps import SHAPES
    from repro.common.tree import tree_count
    import jax
    import jax.numpy as jnp
    from repro.launch.steps import get_model

    model = get_model(cfg.name)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                               jnp.bfloat16, tp=1))
    n_params = tree_count(params)
    if cfg.is_moe:
        # active = non-expert + top_k/n_experts of expert params
        import numpy as np
        expert = sum(int(np.prod(x.shape))
                     for k, x in _named_leaves(params)
                     if "/moe/" in k and "router" not in k)
        n_active = n_params - expert + expert * cfg.top_k / cfg.n_experts
    else:
        n_active = n_params
    sh = SHAPES[shape_name]
    if sh["kind"] == "train":
        tokens = sh["batch"] * sh["seq"]
        return 6.0 * n_active * tokens
    if sh["kind"] == "prefill":
        return 2.0 * n_active * sh["batch"] * sh["seq"]
    if sh["kind"] == "decode":
        return 2.0 * n_active * sh["batch"]          # one token per seq
    if sh["kind"] == "mixed":
        toks = sh["streams"] * sh["chunk"] + sh["batch"]
        return 2.0 * n_active * toks
    return 0.0


def _named_leaves(tree):
    import jax
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        yield key, leaf


def analyze(compiled, *, arch, shape, mesh_name, n_devices, cfg,
            jaxpr=None, flop_divisor=None, outer_mult=1) -> Roofline:
    """Roofline terms from the compiled artifact.

    FLOPs/bytes come from the loop-aware jaxpr walk (global, then divided
    by flop_divisor — the number of devices the heavy ops are actually
    partitioned over); collectives from the loop-aware partitioned-HLO
    parse (already per-device). XLA's own cost_analysis is loop-blind
    (scan bodies counted once) and kept only as a cross-check field.
    """
    from repro.launch.costs import collective_bytes_loop_aware, jaxpr_costs
    div = flop_divisor or n_devices
    if jaxpr is not None:
        jc = jaxpr_costs(jaxpr, outer_mult=outer_mult)
        flops = jc["flops"] / div
        byts = jc["bytes"] / div
    else:  # fallback: loop-blind
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes_loop_aware(hlo)
    mem = compiled.memory_analysis()
    peak = getattr(mem, "peak_memory_in_bytes", 0) or (
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0))
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_dev=flops, bytes_per_dev=byts,
        coll_bytes_per_dev=float(sum(coll.values())),
        coll_breakdown=coll, peak_mem_per_dev=float(peak),
        model_flops=model_flops_estimate(cfg, shape),
    )
