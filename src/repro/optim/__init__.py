from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import warmup_cosine
