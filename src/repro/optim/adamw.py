"""AdamW in raw JAX (no optax in this environment), with optional int8
block-quantized moments (a distributed-optimization memory trick: cuts
optimizer-state HBM ~7x for the grok-1-314b training shape; see
EXPERIMENTS.md §Perf)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

QBLOCK = 256


# --------------------------------------------------- int8 moment encoding --
# Codes are SHAPE-PRESERVING (int8 with the parameter's own shape, scales
# blocked along the last dim) so quantized moments inherit the parameter's
# sharding spec verbatim — no SPMD resharding between the flat-quantized
# and param-shaped layouts (which otherwise triggers involuntary full
# rematerialization / replication collectives at grok-1 scale).
def _q8_encode(x):
    """x [..., n] -> (int8 codes shaped like x, f32 scales [..., nblk])."""
    n = x.shape[-1] if x.ndim else 1
    x2 = x.reshape(x.shape[:-1] + (n,)) if x.ndim else x.reshape(1)
    pad = (-n) % QBLOCK if n >= QBLOCK else 0
    blk = QBLOCK if n >= QBLOCK else n
    xp = jnp.pad(x2, [(0, 0)] * (x2.ndim - 1) + [(0, pad)])
    nblk = xp.shape[-1] // blk
    blocks = xp.reshape(xp.shape[:-1] + (nblk, blk))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    q = q.reshape(xp.shape)[..., :n]
    return q, scale[..., 0]


def _q8_decode(q, scale, shape):
    n = shape[-1] if shape else 1
    blk = QBLOCK if n >= QBLOCK else max(n, 1)
    pad = (-n) % blk
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    nblk = qp.shape[-1] // blk
    blocks = qp.reshape(qp.shape[:-1] + (nblk, blk)).astype(jnp.float32)
    out = (blocks * scale[..., None]).reshape(qp.shape)[..., :n]
    return out.reshape(shape)


class Q8(NamedTuple):
    q: jnp.ndarray
    scale: jnp.ndarray


def adamw_init(params, int8_moments: bool = False):
    def zeros_like_moment(p):
        if int8_moments and p.ndim >= 1 and p.shape[-1] >= 2:
            q, s = _q8_encode(jnp.zeros(p.shape, jnp.float32))
            return Q8(q, s)
        return jnp.zeros(p.shape, jnp.float32)
    mu = jax.tree.map(zeros_like_moment, params)
    nu = jax.tree.map(zeros_like_moment, params)
    return {"mu": mu, "nu": nu, "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0):
    """Returns (new_params, new_opt_state). Master math in fp32."""
    count = opt_state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        int8 = isinstance(mu, Q8)
        mu_f = _q8_decode(mu.q, mu.scale, p.shape) if int8 else mu
        nu_f = _q8_decode(nu.q, nu.scale, p.shape) if int8 else nu
        mu_f = b1 * mu_f + (1 - b1) * g
        nu_f = b2 * nu_f + (1 - b2) * jnp.square(g)
        step = (mu_f / c1) / (jnp.sqrt(nu_f / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        if int8:
            return p_new, Q8(*_q8_encode(mu_f)), Q8(*_q8_encode(nu_f))
        return p_new, mu_f, nu_f

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(opt_state["mu"])
    flat_nu = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu, strict=True)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}
