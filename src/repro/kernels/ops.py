"""Jit'd wrappers around the Pallas kernels.

Handle layout adaptation (model convention <-> kernel tiling), head-dim
padding to the 128-lane VREG width, and automatic interpret-mode on CPU
(the kernels target TPU; on this container they are validated with
interpret=True against the ref.py oracles).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _flash_mod
from repro.kernels import paged_attention as _paged_mod
from repro.kernels import paged_attention_int8 as _paged_i8_mod

LANE = 128


def _interpret_default():
    return jax.default_backend() == "cpu"


def _pad_d(x, d_pad):
    d = x.shape[-1]
    if d == d_pad:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, d_pad - d)])


@partial(jax.jit, static_argnames=("scale", "window", "softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, block_table, kv_lens, q_pos, *,
                    scale, window=None, softcap=None, interpret=None):
    """Model-layout ragged paged attention.

    q [B, Tq, H_p, d]; pages [N, ps, KV_p, d]. Returns [B, Tq, H_p, d].
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, Tq, H_p, d = q.shape
    KV_p = k_pages.shape[2]
    G = H_p // KV_p
    d_pad = ((d + LANE - 1) // LANE) * LANE
    qk = _pad_d(q, d_pad).reshape(B, Tq, KV_p, G, d_pad).transpose(0, 2, 1, 3, 4)
    kp = _pad_d(k_pages, d_pad)
    vp = _pad_d(v_pages, d_pad)
    o = _paged_mod.paged_attention(
        qk, kp, vp, block_table.astype(jnp.int32), kv_lens.astype(jnp.int32),
        q_pos.astype(jnp.int32), scale=scale, window=window, softcap=softcap,
        interpret=interpret)
    o = o.transpose(0, 2, 1, 3, 4).reshape(B, Tq, H_p, d_pad)
    return o[..., :d]


@partial(jax.jit, static_argnames=("scale", "window", "softcap", "interpret"))
def paged_attention_int8(q, k_pages, k_scales, v_pages, v_scales,
                         block_table, kv_lens, q_pos, *,
                         scale, window=None, softcap=None, interpret=None):
    """Model-layout ragged paged attention over int8 pages.

    q [B, Tq, H_p, d] fp; code pages [N, ps, KV_p, d] int8; scale
    sidecars [N, ps, KV_p, 1] f32.  Returns [B, Tq, H_p, d].
    Codes pad with zeros to the 128-lane width — the padded columns
    dequantize to exactly 0 and are sliced off after the kernel.
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, Tq, H_p, d = q.shape
    KV_p = k_pages.shape[2]
    G = H_p // KV_p
    d_pad = ((d + LANE - 1) // LANE) * LANE
    qk = _pad_d(q, d_pad).reshape(B, Tq, KV_p, G, d_pad).transpose(0, 2, 1, 3, 4)
    kp = _pad_d(k_pages, d_pad)
    vp = _pad_d(v_pages, d_pad)
    o = _paged_i8_mod.paged_attention_int8(
        qk, kp, k_scales, vp, v_scales, block_table.astype(jnp.int32),
        kv_lens.astype(jnp.int32), q_pos.astype(jnp.int32), scale=scale,
        window=window, softcap=softcap, interpret=interpret)
    o = o.transpose(0, 2, 1, 3, 4).reshape(B, Tq, H_p, d_pad)
    return o[..., :d]


@partial(jax.jit, static_argnames=("scale", "causal", "window", "softcap",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, kv_lens, *, scale, causal=True, window=None,
                    softcap=None, block_q=128, block_k=128, interpret=None):
    """Model-layout flash attention.

    q [B, T, H_p, d]; k/v [B, Tk, KV_p, d]. Returns [B, T, H_p, d].
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, T, H_p, d = q.shape
    KV_p = k.shape[2]
    G = H_p // KV_p
    d_pad = ((d + LANE - 1) // LANE) * LANE
    qk = _pad_d(q, d_pad).reshape(B, T, KV_p, G, d_pad).transpose(0, 2, 1, 3, 4)
    kk = _pad_d(k, d_pad).transpose(0, 2, 1, 3)
    vk = _pad_d(v, d_pad).transpose(0, 2, 1, 3)
    o = _flash_mod.flash_attention(
        qk, kk, vk, kv_lens.astype(jnp.int32), scale=scale, causal=causal,
        window=window, softcap=softcap,
        block_q=min(block_q, T), block_k=min(block_k, k.shape[1]),
        interpret=interpret)
    o = o.transpose(0, 2, 1, 3, 4).reshape(B, T, H_p, d_pad)
    return o[..., :d]


def paged_attn_model_fn(interpret=None):
    """Adapter matching transformer.default_paged_attn's signature."""
    def fn(q, kpg, vpg, block_table, kv_lens, q_positions, *, scale, window,
           attn_softcap):
        q_pos0 = q_positions[:, 0]
        w = None
        if window is not None:
            import numpy as np
            w = int(window) if not hasattr(window, "aval") else None
            # traced per-layer window (local/global patterns) falls back to
            # the ref path in model code; kernels take static windows.
        return paged_attention(q, kpg, vpg, block_table, kv_lens, q_pos0,
                               scale=scale, window=w, softcap=attn_softcap,
                               interpret=interpret)
    return fn
