"""int8 KV page quantization: codes + per-(token, head) scale sidecar.

Beyond-paper optimization (ROADMAP "Quantized (int8) KV pages"): KV pages
are stored as int8 codes plus one f32 scale per (token, kv-head) and
dequantized on the fly inside the attention kernel.  Page bytes drop from
``hd * itemsize`` to ``hd + 4`` per (token, head), so at equal pool bytes
the allocator carves out ~2x the pages (3.2x on the fp32 reduced models) —
which is exactly the Splitwiser lever: KV capacity, not FLOPs, is what
forces preemptions on the constrained device.

This module is the single entry point for the int8 path:

  * :func:`q8_kv` / :func:`paged_attention_int8` — the canonical quantizer
    and the jnp reference attention (dequant fused into the flash scan via
    ``k_scale``/``v_scale``); promoted here from ``launch/spmd.py``, which
    now re-exports them.
  * :func:`int8_decode_attn` / :func:`int8_chunk_attn` — drop-in
    ``default_decode_attn`` / ``default_chunk_attn`` replacements over
    page *dicts* ``{"q": int8 codes [.., hd], "s": f32 scales [.., 1]}``.
    ``jax.lax.scan`` carries dict pytrees through ``transformer.decode`` /
    ``transformer.mixed`` unchanged, so the engine flips paths by swapping
    ``attn_fn`` and the page pytree only.
  * the Pallas dequant-in-kernel variant lives in
    ``kernels/paged_attention_int8.py`` (TPU tiling; validated in
    interpret mode against :func:`paged_attention_int8` here).

Accuracy: per-(token, head) symmetric quantization keeps relative
attention-output error ~1e-3 (tests/test_int8_kv.py); greedy streams on
the tier-1 workloads match the fp oracle token-for-token (the gap only
matters when two logits sit closer than the attention perturbation —
``pressure_kv_int8`` reports the per-token fp agreement on longer runs).
Cross-MODE int8 streams are bit-identical by construction: every
attention path reads dequantized values for every key — chunked paths
re-read committed pages, the monolithic prefill applies
:func:`fake_quant_kv` — so chunk boundaries cancel out exactly as in fp.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import flash_attention, gather_pages
from repro.models.transformer import write_kv_chunk, write_kv_token

# Floor on the stored scale: an all-zero (token, head) row — zero-init
# pool pages, padding tokens — must carry a positive finite scale so
# dequant is exactly 0.0, never 0/0 = NaN, and the sanitizer's sidecar
# checks can treat scale > 0 as "this entry is live".
SCALE_FLOOR = 1e-20


def q8_kv(t):
    """t [..., hd] -> (int8 codes, f32 scale [..., 1]).

    Symmetric per-(token, head) quantization: scale = maxabs/127, floored
    at :data:`SCALE_FLOOR` (all-zero rows stay exactly representable).
    """
    t32 = t.astype(jnp.float32)
    scale = jnp.maximum(
        jnp.max(jnp.abs(t32), axis=-1, keepdims=True) / 127.0, SCALE_FLOOR)
    q = jnp.round(t32 / scale)
    return q.astype(jnp.int8), scale


def paged_attention_int8(q, kpg, kps, vpg, vps, block_table, kv_lens,
                         q_positions, *, scale, window, attn_softcap):
    """paged attention over int8 pages (codes kpg/vpg + scales kps/vps).

    jnp reference path: gathers the sequence's pages and runs the flash
    scan with ``k_scale``/``v_scale``, so dequant happens inside the
    blockwise loop (codes travel through HBM, floats never materialize
    per-page).  The Pallas TPU variant mirrors this exactly.
    """
    B, Pmax = block_table.shape
    ps = kpg.shape[1]
    k = gather_pages(kpg, block_table)
    v = gather_pages(vpg, block_table)
    ks = gather_pages(kps, block_table)
    vs = gather_pages(vps, block_table)
    kv_pos = jnp.broadcast_to(
        jnp.arange(Pmax * ps, dtype=jnp.int32)[None], (B, Pmax * ps))
    return flash_attention(
        q, k, v, q_positions=q_positions, kv_positions=kv_pos,
        kv_valid_len=kv_lens, scale=scale, causal=True, window=window,
        attn_softcap=attn_softcap, block_kv=min(512, Pmax * ps),
        k_scale=ks, v_scale=vs)


def quant_kv(k, v):
    """fp K/V rows -> ({"q", "s"}, {"q", "s"}) int8 code+scale dicts."""
    kq, ks = q8_kv(k)
    vq, vs = q8_kv(v)
    return {"q": kq, "s": ks}, {"q": vq, "s": vs}


def fake_quant_kv(t):
    """Quantize-dequantize ``t`` through the page representation.

    Applied to K/V at the attention input of the MONOLITHIC prefill
    (sequential mode computes the whole prompt in one shot) so its
    numerics match the streamed/chunked paths, which re-read earlier
    chunks from quantized pages: with it, every key any query attends
    to is the dequantized value in EVERY mode, and greedy int8 streams
    become chunk-invariant — bit-identical across serve modes and
    ``prefill_chunk``/``chunk_tokens`` settings, exactly like fp.
    Commit still quantizes the fp values: :func:`q8_kv` is idempotent
    (the maxabs element always maps to code 127, so requantizing the
    dequantized row reproduces the same codes and scale).
    """
    q, s = q8_kv(t)
    return (q.astype(jnp.float32) * s).astype(t.dtype)


def int8_decode_attn(q, k_new, v_new, kpg, vpg, block_table, seq_lens,
                     active, *, scale, window, attn_softcap):
    """``default_decode_attn`` over int8 page dicts.

    q [B,1,H_p,hd]; k_new/v_new [B,KV_p,hd] fp; kpg/vpg ``{"q", "s"}``.
    Quantizes the new token at write, attends with in-scan dequant.
    """
    kn, vn = quant_kv(k_new, v_new)
    kc, vc = write_kv_token(kpg["q"], vpg["q"], kn["q"], vn["q"],
                            block_table, seq_lens, active)
    ksc, vsc = write_kv_token(kpg["s"], vpg["s"], kn["s"], vn["s"],
                              block_table, seq_lens, active)
    kpg = {"q": kc, "s": ksc}
    vpg = {"q": vc, "s": vsc}
    o = paged_attention_int8(q, kpg["q"], kpg["s"], vpg["q"], vpg["s"],
                             block_table, seq_lens + 1, seq_lens[:, None],
                             scale=scale, window=window,
                             attn_softcap=attn_softcap)
    return o, kpg, vpg


def int8_chunk_attn(q, k_new, v_new, kpg, vpg, block_table, start, lens, *,
                    scale, window, attn_softcap):
    """``default_chunk_attn`` over int8 page dicts.

    q [P,C,H_p,hd]; k_new/v_new [P,C,KV_p,hd] fp; kpg/vpg ``{"q", "s"}``.
    """
    kn, vn = quant_kv(k_new, v_new)
    kc, vc = write_kv_chunk(kpg["q"], vpg["q"], kn["q"], vn["q"],
                            block_table, start, lens)
    ksc, vsc = write_kv_chunk(kpg["s"], vpg["s"], kn["s"], vn["s"],
                              block_table, start, lens)
    kpg = {"q": kc, "s": ksc}
    vpg = {"q": vc, "s": vsc}
    C = q.shape[1]
    q_pos = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    o = paged_attention_int8(q, kpg["q"], kpg["s"], vpg["q"], vpg["s"],
                             block_table, start + lens, q_pos,
                             scale=scale, window=window,
                             attn_softcap=attn_softcap)
    return o, kpg, vpg


def init_pages_int8(cfg, n_pages, page_size, tp=1, n_layers=None):
    """int8 page pools: ({"q", "s"}, {"q", "s"}) zero-initialized.

    Codes [L, N, ps, KV_p, hd] int8; scales [L, N, ps, KV_p, 1] f32
    (floored — a zero-filled scale plane would make the all-zero pool
    rows un-representable, see :data:`SCALE_FLOOR`).
    """
    from repro.models.transformer import gqa_layout
    _, KV_p, _, _, _ = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, n_pages, page_size, KV_p)
    k = {"q": jnp.zeros(shape + (cfg.head_dim,), jnp.int8),
         "s": jnp.full(shape + (1,), SCALE_FLOOR, jnp.float32)}
    v = {"q": jnp.zeros(shape + (cfg.head_dim,), jnp.int8),
         "s": jnp.full(shape + (1,), SCALE_FLOOR, jnp.float32)}
    return k, v


def kv_page_bytes(cfg, page_size, fp_dtype, *, kv_dtype="fp", tp=1):
    """Bytes ONE page costs in device memory (K + V, all layers).

    fp pages: ``2 * L * ps * KV_p * hd * itemsize``; int8 pages add the
    f32 scale sidecar per (token, head): ``2 * L * ps * KV_p * (hd + 4)``.
    This is the denominator for the byte-denominated pool: at equal pool
    bytes the int8 path yields ``hd*itemsize / (hd+4)`` times the pages.
    """
    from repro.models.transformer import gqa_layout
    _, KV_p, _, _, _ = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    per_tok_head = (cfg.head_dim + 4 if kv_dtype == "int8"
                    else cfg.head_dim * jnp.dtype(fp_dtype).itemsize)
    return 2 * cfg.n_layers * page_size * KV_p * per_tok_head
