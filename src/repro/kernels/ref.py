"""Pure-jnp oracles for the Pallas kernels.

These are the same functions the models use on the XLA-native path
(repro.models.layers), re-exported under kernel-facing signatures so the
kernel tests sweep one call site.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import flash_attention as _flash_ref
from repro.models.layers import paged_attention_ref as _paged_ref


def paged_attention_ref(q, k_pages, v_pages, block_table, kv_lens, q_pos, *,
                        scale, window=None, softcap=None):
    """q [B, KV_p, C, G, d] (kernel layout) -> o same shape."""
    B, KV_p, C, G, d = q.shape
    # kernel layout -> model layout [B, C, H_p, d] with H_p = KV_p * G
    qm = q.transpose(0, 2, 1, 3, 4).reshape(B, C, KV_p * G, d)
    q_positions = q_pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    o = _paged_ref(qm, k_pages, v_pages, block_table, kv_lens, q_positions,
                   scale=scale, window=window, attn_softcap=softcap)
    return o.reshape(B, C, KV_p, G, d).transpose(0, 2, 1, 3, 4)


def flash_attention_ref(q, k, v, kv_lens, *, scale, causal=True, window=None,
                        softcap=None):
    """q [B, KV_p, T, G, d]; k/v [B, KV_p, Tk, d] -> o like q."""
    B, KV_p, T, G, d = q.shape
    Tk = k.shape[2]
    qm = q.transpose(0, 2, 1, 3, 4).reshape(B, T, KV_p * G, d)
    km = k.transpose(0, 2, 1, 3)                     # [B, Tk, KV_p, d]
    vm = v.transpose(0, 2, 1, 3)
    q_positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    kv_positions = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32)[None], (B, Tk))
    o = _flash_ref(qm, km, vm, q_positions=q_positions,
                   kv_positions=kv_positions, kv_valid_len=kv_lens,
                   scale=scale, causal=causal, window=window,
                   attn_softcap=softcap)
    return o.reshape(B, T, KV_p, G, d).transpose(0, 2, 1, 3, 4)
