"""Pallas TPU kernel: ragged paged attention over int8 KV pages.

Same grid/tiling as ``kernels/paged_attention.py`` — one kernel for both
inference phases (decode C=1, chunked prefill C=chunk) — but the K/V page
pool streams through VMEM as int8 codes plus an f32 per-(token, head)
scale sidecar, and dequantization happens *inside* the kernel right
before the MXU matmuls.  HBM traffic per page drops from ``ps*KV_p*hd``
floats to ``ps*KV_p*hd`` bytes + ``ps*KV_p`` scales: on the
bandwidth-bound decode phase that is a ~2x (fp16) to ~3.2x (fp32)
reduction, on top of the equal-bytes capacity win the byte-denominated
allocator takes.

Layout:
  q         [B, KV_p, C, G, d]  fp (padded layout, as the fp kernel)
  k_pages   [N, ps, KV_p, d]    int8 codes
  k_scales  [N, ps, KV_p, 1]    f32 (sidecar rides the same page table;
                                on TPU the unit lane is tolerable — the
                                sidecar is 1/(d) of the code bytes)
  v_pages / v_scales            likewise
  block_table [B, Pmax] int32 (scalar-prefetched), kv_lens/q_pos [B]

The scale BlockSpecs reuse the code pages' index_map, so the DMA engine
follows one page table for all four operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar-prefetch refs
    block_table_ref,    # [B, Pmax]
    kv_lens_ref,        # [B]
    q_pos_ref,          # [B]
    # array refs
    q_ref,              # [1, 1, C, G, d]        fp
    k_ref,              # [1, ps, 1, d]          int8
    ks_ref,             # [1, ps, 1, 1]          f32
    v_ref,              # [1, ps, 1, d]          int8
    vs_ref,             # [1, ps, 1, 1]          f32
    o_ref,              # [1, 1, C, G, d]
    # scratch
    m_ref,              # [C*G, 128] f32
    l_ref,              # [C*G, 128] f32
    acc_ref,            # [C*G, d] f32
    *,
    scale: float,
    page_size: int,
    window: int | None,
    softcap: float | None,
):
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kv_lens_ref[b]
    start = i * page_size

    @pl.when(start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [C, G, d]
        C, G, d = q.shape
        # dequant in VMEM: int8 codes * per-token scale, fp never touches HBM
        k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0]   # [ps, d]
        v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0]
        q2 = q.reshape(C * G, d)
        logits = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # [C*G, ps]
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        kv_pos = start + jax.lax.broadcasted_iota(jnp.int32, (C * G, page_size), 1)
        row = jax.lax.broadcasted_iota(jnp.int32, (C * G, page_size), 0)
        qp = q_pos_ref[b] + row // G                         # query position
        mask = (kv_pos < kv_len) & (kv_pos <= qp)
        if window is not None:
            mask &= kv_pos > qp - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(
            p.astype(jnp.float32), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # [C*G, d]
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(i == n_pages - 1)
    def _finalize():
        C, G = o_ref.shape[2], o_ref.shape[3]
        l = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / l).reshape(C, G, -1).astype(o_ref.dtype)


def paged_attention_int8(
    q,                      # [B, KV_p, C, G, d] fp
    k_pages, k_scales,      # [N, ps, KV_p, d] int8 / [N, ps, KV_p, 1] f32
    v_pages, v_scales,
    block_table,            # [B, Pmax] int32
    kv_lens,                # [B] int32
    q_pos,                  # [B] int32 (position of first query row per seq)
    *,
    scale: float,
    window=None,
    softcap=None,
    interpret: bool = False,
):
    """Returns o [B, KV_p, C, G, d] in q's dtype."""
    # argument contract — same RPR008 discipline as the fp launcher: a
    # shape/dtype mistake dies here with a message, not as an opaque
    # Mosaic lowering error (all checks on static shapes: free once jitted)
    if q.ndim != 5:
        raise ValueError(f"q must be [B, KV_p, C, G, d], got shape {q.shape}")
    B, KV_p, C, G, d = q.shape
    if jnp.issubdtype(q.dtype, jnp.integer):
        raise ValueError(f"q must be floating-point, got {q.dtype}")
    if k_pages.ndim != 4 or k_pages.shape != v_pages.shape:
        raise ValueError(
            f"k_pages/v_pages must share shape [N, ps, KV_p, d], got "
            f"{k_pages.shape} vs {v_pages.shape}")
    if k_pages.dtype != jnp.int8 or v_pages.dtype != jnp.int8:
        raise ValueError(
            f"k_pages/v_pages must be int8 codes, got {k_pages.dtype}/"
            f"{v_pages.dtype}")
    N, ps, _, _ = k_pages.shape
    if k_pages.shape[2:] != (KV_p, d):
        raise ValueError(
            f"k_pages trailing dims {k_pages.shape[2:]} disagree with q's "
            f"(KV_p, d) = {(KV_p, d)}")
    if k_scales.shape != v_scales.shape or k_scales.shape != (N, ps, KV_p, 1):
        raise ValueError(
            f"k_scales/v_scales must be [N={N}, ps={ps}, KV_p={KV_p}, 1], "
            f"got {k_scales.shape} vs {v_scales.shape}")
    if k_scales.dtype != jnp.float32 or v_scales.dtype != jnp.float32:
        raise ValueError(
            f"scale sidecars must be float32, got {k_scales.dtype}/"
            f"{v_scales.dtype}")
    if block_table.ndim != 2 or block_table.shape[0] != B:
        raise ValueError(
            f"block_table must be [B={B}, Pmax], got {block_table.shape}")
    for name, arr in (("block_table", block_table), ("kv_lens", kv_lens),
                      ("q_pos", q_pos)):
        if not jnp.issubdtype(arr.dtype, jnp.integer):
            raise ValueError(f"{name} must be integer-typed, got {arr.dtype}")
    if kv_lens.shape != (B,) or q_pos.shape != (B,):
        raise ValueError(
            f"kv_lens/q_pos must be [B={B}], got {kv_lens.shape} / "
            f"{q_pos.shape}")
    Pmax = block_table.shape[1]

    grid = (B, KV_p, Pmax)

    def q_map(b, h, i, *_):
        return (b, h, 0, 0, 0)

    def kv_map(b, h, i, block_table_ref, kv_lens_ref, q_pos_ref):
        return (block_table_ref[b, i], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, C, G, d), q_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
            pl.BlockSpec((1, ps, 1, 1), kv_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
            pl.BlockSpec((1, ps, 1, 1), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, C, G, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((C * G, 128), jnp.float32),
            pltpu.VMEM((C * G, 128), jnp.float32),
            pltpu.VMEM((C * G, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, scale=scale, page_size=ps,
        window=None if window is None else int(window),
        softcap=None if softcap is None else float(softcap))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table, kv_lens, q_pos, q, k_pages, k_scales, v_pages, v_scales)
