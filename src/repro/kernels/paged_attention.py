"""Pallas TPU kernel: ragged paged attention (the Splitwiser serving kernel).

One kernel covers BOTH inference phases:
  * decode        — C = 1 query token per sequence (bandwidth-bound: streams
                    the sequence's KV pages from HBM through VMEM once);
  * chunked prefill — C = chunk query tokens attending to paged history +
                    freshly written self KV (compute-bound).

Layout / tiling:
  q        [B, KV_p, C, G, d]   (G = q heads per kv head, padded layout)
  k_pages  [N, ps, KV_p, d]     (page pool)
  v_pages  [N, ps, KV_p, d]
  block_table [B, Pmax] int32   (scalar-prefetched -> page indirection
                                 happens in the BlockSpec index_map, i.e.
                                 the DMA engine follows the page table)
  kv_lens  [B] int32            valid KV length per sequence
  q_pos    [B] int32            position of the first query row

Grid (B, KV_p, Pmax): the page loop is the innermost (sequential) grid
dimension; online-softmax state lives in VMEM scratch across it.
VMEM working set per step: ps*d (K) + ps*d (V) + C*G*d (Q/acc) floats —
e.g. ps=64, d=128, C*G<=256: ~64-192 KiB, comfortably inside VMEM.
MXU work per step: (C*G, d) x (d, ps) and (C*G, ps) x (ps, d) matmuls —
d and ps chosen as multiples of 128/64 to keep the systolic array full.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar-prefetch refs
    block_table_ref,    # [B, Pmax]
    kv_lens_ref,        # [B]
    q_pos_ref,          # [B]
    # array refs
    q_ref,              # [1, 1, C, G, d]
    k_ref,              # [1, ps, 1, d]
    v_ref,              # [1, ps, 1, d]
    o_ref,              # [1, 1, C, G, d]
    # scratch
    m_ref,              # [C*G, 128] f32
    l_ref,              # [C*G, 128] f32
    acc_ref,            # [C*G, d] f32
    *,
    scale: float,
    page_size: int,
    window: int | None,
    softcap: float | None,
):
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kv_lens_ref[b]
    start = i * page_size

    @pl.when(start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [C, G, d]
        C, G, d = q.shape
        k = k_ref[0, :, 0].astype(jnp.float32)               # [ps, d]
        v = v_ref[0, :, 0].astype(jnp.float32)
        q2 = q.reshape(C * G, d)
        logits = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # [C*G, ps]
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        kv_pos = start + jax.lax.broadcasted_iota(jnp.int32, (C * G, page_size), 1)
        row = jax.lax.broadcasted_iota(jnp.int32, (C * G, page_size), 0)
        qp = q_pos_ref[b] + row // G                         # query position
        mask = (kv_pos < kv_len) & (kv_pos <= qp)
        if window is not None:
            mask &= kv_pos > qp - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(
            p.astype(jnp.float32), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # [C*G, d]
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(i == n_pages - 1)
    def _finalize():
        C, G = o_ref.shape[2], o_ref.shape[3]
        l = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / l).reshape(C, G, -1).astype(o_ref.dtype)


def paged_attention(
    q,                  # [B, KV_p, C, G, d]
    k_pages, v_pages,   # [N, ps, KV_p, d]
    block_table,        # [B, Pmax] int32
    kv_lens,            # [B] int32
    q_pos,              # [B] int32 (position of first query row per seq)
    *,
    scale: float,
    window=None,
    softcap=None,
    interpret: bool = False,
):
    """Returns o [B, KV_p, C, G, d]."""
    # argument contract — shape/dtype mistakes must die here with a
    # message, not as an opaque Mosaic lowering error (all checks are on
    # static shapes/dtypes: zero cost once jitted)
    if q.ndim != 5:
        raise ValueError(f"q must be [B, KV_p, C, G, d], got shape {q.shape}")
    B, KV_p, C, G, d = q.shape
    if k_pages.ndim != 4 or k_pages.shape != v_pages.shape:
        raise ValueError(
            f"k_pages/v_pages must share shape [N, ps, KV_p, d], got "
            f"{k_pages.shape} vs {v_pages.shape}")
    N, ps, _, _ = k_pages.shape
    if k_pages.shape[2:] != (KV_p, d):
        raise ValueError(
            f"k_pages trailing dims {k_pages.shape[2:]} disagree with q's "
            f"(KV_p, d) = {(KV_p, d)}")
    if k_pages.dtype != v_pages.dtype or q.dtype != k_pages.dtype:
        raise ValueError(
            f"q/k_pages/v_pages dtypes must match, got {q.dtype}/"
            f"{k_pages.dtype}/{v_pages.dtype}")
    if block_table.ndim != 2 or block_table.shape[0] != B:
        raise ValueError(
            f"block_table must be [B={B}, Pmax], got {block_table.shape}")
    for name, arr in (("block_table", block_table), ("kv_lens", kv_lens),
                      ("q_pos", q_pos)):
        if not jnp.issubdtype(arr.dtype, jnp.integer):
            raise ValueError(f"{name} must be integer-typed, got {arr.dtype}")
    if kv_lens.shape != (B,) or q_pos.shape != (B,):
        raise ValueError(
            f"kv_lens/q_pos must be [B={B}], got {kv_lens.shape} / "
            f"{q_pos.shape}")
    Pmax = block_table.shape[1]

    grid = (B, KV_p, Pmax)

    def q_map(b, h, i, *_):
        return (b, h, 0, 0, 0)

    def kv_map(b, h, i, block_table_ref, kv_lens_ref, q_pos_ref):
        return (block_table_ref[b, i], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, C, G, d), q_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
            pl.BlockSpec((1, ps, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, C, G, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((C * G, 128), jnp.float32),
            pltpu.VMEM((C * G, 128), jnp.float32),
            pltpu.VMEM((C * G, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, scale=scale, page_size=ps,
        window=None if window is None else int(window),
        softcap=None if softcap is None else float(softcap))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table, kv_lens, q_pos, q, k_pages, v_pages)
