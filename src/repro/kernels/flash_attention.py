"""Pallas TPU kernel: causal flash attention (prompt-phase / training).

The compute-bound phase of the paper: all prompt tokens processed in
parallel, MXU-saturating [bq*G, d] x [d, bk] tiles with online softmax in
VMEM scratch. Supports GQA (grouped layout), sliding windows (gemma2) and
attention-logit softcaps.

Layout:
  q [B, KV_p, T, G, d]   k/v [B, KV_p, Tk, d]
Grid (B, KV_p, nq, nk), nk innermost; causal upper-triangle blocks are
skipped with pl.when (half the FLOPs of a naive masked implementation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    kv_lens_ref,        # [B] scalar prefetch
    q_ref,              # [1, 1, bq, G, d]
    k_ref,              # [1, 1, bk, d]
    v_ref,              # [1, 1, bk, d]
    o_ref,              # [1, 1, bq, G, d]
    m_ref, l_ref, acc_ref,
    *, scale, bq, bk, window, softcap, causal,
):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # skip blocks that are entirely above the causal diagonal or entirely
    # outside the sliding window
    run = jnp.bool_(True)
    if causal:
        run = run & (k_start <= q_start + bq - 1)
    if window is not None:
        run = run & (k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, G, d]
        G, d = q.shape[1], q.shape[2]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        q2 = q.reshape(bq * G, d)
        logits = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # [bq*G, bk]
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        kv_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq * G, bk), 1)
        row = jax.lax.broadcasted_iota(jnp.int32, (bq * G, bk), 0)
        qp = q_start + row // G
        mask = kv_pos < kv_lens_ref[b]
        if causal:
            mask &= kv_pos <= qp
        if window is not None:
            mask &= kv_pos > qp - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ik == nk - 1)
    def _finalize():
        G = o_ref.shape[3]
        l = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / l).reshape(bq, G, -1).astype(o_ref.dtype)


def flash_attention(
    q,                  # [B, KV_p, T, G, d]
    k, v,               # [B, KV_p, Tk, d]
    kv_lens,            # [B] int32
    *,
    scale: float,
    causal: bool = True,
    window=None,
    softcap=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    # argument contract (static shapes/dtypes: free once jitted)
    if q.ndim != 5:
        raise ValueError(f"q must be [B, KV_p, T, G, d], got shape {q.shape}")
    B, KV_p, T, G, d = q.shape
    if k.shape != v.shape or k.ndim != 4:
        raise ValueError(
            f"k/v must share shape [B, KV_p, Tk, d], got {k.shape} vs "
            f"{v.shape}")
    if k.shape[0] != B or k.shape[1] != KV_p or k.shape[3] != d:
        raise ValueError(
            f"k shape {k.shape} disagrees with q's (B, KV_p, ..., d) = "
            f"{(B, KV_p, d)}")
    if q.dtype != k.dtype or k.dtype != v.dtype:
        raise ValueError(
            f"q/k/v dtypes must match, got {q.dtype}/{k.dtype}/{v.dtype}")
    if kv_lens.shape != (B,) or not jnp.issubdtype(kv_lens.dtype, jnp.integer):
        raise ValueError(
            f"kv_lens must be integer [B={B}], got {kv_lens.shape} "
            f"{kv_lens.dtype}")
    Tk = k.shape[2]
    bq = min(block_q, T)
    bk = min(block_k, Tk)
    if T % bq or Tk % bk:
        raise ValueError(
            f"sequence lengths must tile evenly: T={T} vs block_q={bq}, "
            f"Tk={Tk} vs block_k={bk}")
    grid = (B, KV_p, T // bq, Tk // bk)

    def q_map(b, h, iq, ik, *_):
        return (b, h, iq, 0, 0)

    def kv_map(b, h, iq, ik, *_):
        return (b, h, ik, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, G, d), q_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, G, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq * G, 128), jnp.float32),
            pltpu.VMEM((bq * G, 128), jnp.float32),
            pltpu.VMEM((bq * G, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, scale=scale, bq=bq, bk=bk,
        window=None if window is None else int(window),
        softcap=None if softcap is None else float(softcap),
        causal=causal)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(kv_lens, q, k, v)
