"""Pallas TPU kernel: Mamba2 SSD chunkwise scan (zamba2 prefill hot spot).

Per (batch, head) program, the chunk loop is the innermost grid dimension
with the SSM state h [P, N] carried in VMEM scratch — the HBM traffic is
exactly the x/B/C streams plus the y output (what the tagged jnp scan
models). MXU work per chunk: [Q,N]x[N,Q], [Q,Q]x[Q,P], [Q,N]x[N,P],
[P,Q]x[Q,N] matmuls with Q=chunk, P=head dim (64), N=state (64).

Layouts:
  xdt [B, H, T, P]  (dt-scaled inputs)   la [B, H, T] log-decay (<=0)
  Bc, Cc [B, T, N]  (shared across heads)
Outputs: y [B, H, T, P], h_final [B, H, P, N].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xdt_ref, la_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
            chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = xdt_ref[0, 0].astype(jnp.float32)          # [Q, P]
    la = la_ref[0, 0].astype(jnp.float32)          # [Q]
    bq = b_ref[0].astype(jnp.float32)              # [Q, N]
    cq = c_ref[0].astype(jnp.float32)              # [Q, N]
    Q = x.shape[0]
    L = jnp.cumsum(la)                             # [Q]
    # intra-chunk: y[t] = sum_{i<=t} exp(L_t - L_i) (C_t.B_i) x_i
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(row >= col, jnp.exp(L[:, None] - L[None, :]), 0.0)
    G = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, Q]
    y = jax.lax.dot_general(G * M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]
    # inter-chunk: y[t] += exp(L_t) C_t . h      (h [P, N])
    ch = jax.lax.dot_general(cq, h_ref[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, P]
    y = y + ch * jnp.exp(L)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state: h' = exp(L_last) h + sum_i exp(L_last - L_i) x_i B_i^T
    decay = jnp.exp(L[Q - 1] - L)                  # [Q]
    xw = x * decay[:, None]                        # [Q, P]
    hb = jax.lax.dot_general(xw, bq, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P, N]
    h_ref[...] = jnp.exp(L[Q - 1]) * h_ref[...] + hb

    @pl.when(ci == nc - 1)
    def _final():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


def ssd_chunk_scan(xdt, la, Bc, Cc, *, chunk: int = 64,
                   interpret: bool = False):
    """xdt [B,H,T,P]; la [B,H,T]; Bc/Cc [B,T,N] -> (y [B,H,T,P],
    h_final [B,H,P,N]). T must be a multiple of chunk."""
    # argument contract (static shapes: free once jitted)
    if xdt.ndim != 4:
        raise ValueError(f"xdt must be [B, H, T, P], got shape {xdt.shape}")
    B, H, T, P = xdt.shape
    if la.shape != (B, H, T):
        raise ValueError(
            f"la must be [B, H, T] = {(B, H, T)}, got {la.shape}")
    if Bc.shape != Cc.shape or Bc.ndim != 3 or Bc.shape[:2] != (B, T):
        raise ValueError(
            f"Bc/Cc must share shape [B={B}, T={T}, N], got {Bc.shape} vs "
            f"{Cc.shape}")
    N = Bc.shape[-1]
    if T % chunk:
        raise ValueError(f"T={T} must be a multiple of chunk={chunk}")
    nc = T // chunk
    grid = (B, H, nc)

    out_y = jax.ShapeDtypeStruct((B, H, T, P), xdt.dtype)
    out_h = jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[out_y, out_h],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, la, Bc, Cc)
