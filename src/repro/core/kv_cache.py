"""Host-side paged KV-cache bookkeeping.

The device-side page pool is a plain array [L, N, ps, KV_p, hd] owned by
the engine; this module owns the allocator + per-request block tables —
the paper's "mapping between the inference request ... and the generated
KV-cache file" (§II-G), solved with block tables instead of files.

Pages are **refcounted**: with a :class:`~repro.core.prefix_cache.PrefixCache`
attached, byte-identical prefixes across requests map to the *same*
pages (``share``), a cached page whose refcount drops to zero parks on
the cache's reclaimable list instead of the free list (still serving
future hits, stripped leaf-first under pressure before the scheduler
preempts anyone), ``prepare_write`` copy-on-writes a shared or cached
page before a token write would mutate it, and ``cow_partial`` turns a
token-level (partial-page) cache hit into a private copy of the donor
page so the matched span is reused without recomputation.

Page N-1 is reserved as the trash page (inactive batch slots scatter
there); it is never allocated.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.prefix_cache import PrefixCache


class OutOfPages(Exception):
    pass


def pool_pages_from_bytes(budget_bytes: int, page_bytes: int) -> int:
    """Byte-denominated pool sizing: pages (incl. the reserved trash
    page) a device-byte budget buys at ``page_bytes`` per page.  This is
    what makes ``kv_dtype="int8"`` a capacity lever: the same budget over
    smaller pages yields proportionally more of them.
    """
    if page_bytes <= 0:
        raise ValueError(f"page_bytes must be positive, got {page_bytes}")
    n = budget_bytes // page_bytes
    if n < 2:
        raise ValueError(
            f"kv_pool_bytes={budget_bytes} buys {n} page(s) of "
            f"{page_bytes} bytes; the pool needs >= 2 (one is the "
            "reserved trash page) — raise the budget or shrink page_size")
    return n


class KVQuantSidecar:
    """Host-side model of the int8 scale sidecar.

    Every device page written with quantized KV carries exactly one scale
    entry per (token, head) plane; this mirror tracks *which pages* hold
    live quantized contents so the sanitizer can check the sidecar
    invariant (``scale_sidecar``): entry count is exactly 1 for every
    written live/cached page, no entry survives a page's return to the
    free list, and pool bytes conserve (codes + scales = page_bytes *
    n_pages).  Maintained by the engine at every commit/COW site and from
    allocator ``cow`` / ``reclaim`` / ``page_free`` events.
    """

    def __init__(self) -> None:
        self.entries: Dict[int, int] = {}   # page -> scale-entry count
        self.n_quant_pages = 0              # cumulative fresh quantized pages

    def note_write(self, pages) -> None:
        """Pages just committed with quantized KV (idempotent: decode
        re-writes the tail page every token without re-registering)."""
        for p in pages:
            if p not in self.entries:
                self.n_quant_pages += 1
                self.entries[p] = 1

    def note_copy(self, src: int, dst: int) -> None:
        """A COW device copy carried ``src``'s codes+scales to ``dst``."""
        if src in self.entries:
            if dst not in self.entries:
                self.n_quant_pages += 1
            self.entries[dst] = self.entries[src]

    def drop(self, page: int) -> None:
        """``page`` returned to the free list; its sidecar entry dies
        with it (the next owner re-quantizes from scratch)."""
        self.entries.pop(page, None)


@dataclass
class PageAllocator:
    n_pages: int
    page_size: int
    cache: Optional[PrefixCache] = None
    # scheduler-trace hook: called as event_cb(event, **detail) on
    # reclaim/cow/page_free
    event_cb: Optional[Callable] = None
    # device bytes one page costs (codes + any scale sidecar, K+V, all
    # layers); 0 = unsized (legacy direct construction).  Set by the
    # engine from kernels.kv_int8.kv_page_bytes so pool capacity is
    # byte-denominated and the sanitizer can check byte conservation.
    page_bytes: int = 0
    _free: List[int] = field(default_factory=list)
    _owned: Dict[int, List[int]] = field(default_factory=dict)  # rid -> pages
    _ref: Dict[int, int] = field(default_factory=dict)          # page -> refs
    # rid -> free-pool capacity consumed since its last begin_admission():
    # fresh allocs + reclaimable revives + COW copies.  The sanitizer checks
    # this against the pages the scheduler charged at admission.
    _consumed: Dict[int, int] = field(default_factory=dict)
    n_reclaims: int = 0      # cached pages stripped back into the free list
    n_cow: int = 0           # copy-on-write page splits
    n_shared_maps: int = 0   # cache-hit pages mapped via share()
    n_partial_cow: int = 0   # token-level (partial-page) hit copies

    def __post_init__(self):
        # last page reserved as trash
        self._free = list(range(self.n_pages - 2, -1, -1))

    @property
    def trash_page(self) -> int:
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        """Pages allocatable right now: the free list plus the cache's
        reclaimable pool (zero-ref cached pages are stripped on demand)."""
        return len(self._free) + (self.cache.n_reclaimable if self.cache else 0)

    @property
    def n_allocated(self) -> int:
        return (self.n_pages - 1) - self.n_free

    @property
    def n_pages_shared(self) -> int:
        """Pages currently mapped by more than one request."""
        return sum(1 for c in self._ref.values() if c > 1)

    def usage(self) -> float:
        """KV-cache usage fraction (the paper's Fig. 5/14/15 metric).
        Reclaimable cached pages count as free: they are reusable capacity."""
        return self.n_allocated / (self.n_pages - 1)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def is_referenced(self, page: int) -> bool:
        """True when the page is mapped by at least one live request.
        (A reclaimable cache hit is NOT referenced: reviving it consumes
        free capacity, so admission must budget it like a fresh alloc.)"""
        return self._ref.get(page, 0) > 0

    def ref_count(self, page: int) -> int:
        """Live-request references on ``page`` (0 = unmapped/reclaimable)."""
        return self._ref.get(page, 0)

    def n_exclusive(self, rid: int) -> int:
        """Pages only ``rid`` references — the capacity that freeing it
        would actually return (shared pages merely decref)."""
        return sum(1 for p in self._owned.get(rid, ())
                   if self._ref.get(p, 0) == 1)

    def can_alloc(self, n: int) -> bool:
        return self.n_free >= n

    def begin_admission(self, rid: int) -> None:
        """Reset ``rid``'s consumed-capacity counter; the scheduler calls
        this at admission so the sanitizer can bound what the prefill
        actually takes from the free pool against the admission budget."""
        self._consumed[rid] = 0

    def consumed(self, rid: int) -> int:
        """Free-pool capacity ``rid`` consumed since its admission."""
        return self._consumed.get(rid, 0)

    def _consume(self, rid: int, n: int = 1) -> None:
        self._consumed[rid] = self._consumed.get(rid, 0) + n

    def _event(self, ev: str, **detail) -> None:
        if self.event_cb is not None:
            self.event_cb(ev, **detail)

    def _pop_free(self, rid: int) -> int:
        """Take one page, stripping the reclaimable cache pool if the free
        list is dry (this — not preemption — is the first pressure valve)."""
        if not self._free and self.cache is not None:
            # strip order = the cache's EvictionPolicy (built by the engine
            # from ServeConfig.resolved_eviction_policy)
            page = self.cache.pop_reclaimable()
            if page is not None:
                self.n_reclaims += 1
                self._event("reclaim", rid=rid, page=page,
                            cost=self.cache.last_evict_cost)
                self._free.append(page)
        if not self._free:
            raise OutOfPages(f"need 1, have {self.n_free}")
        return self._free.pop()

    def alloc(self, rid: int, n: int) -> List[int]:
        if self.n_free < n:
            raise OutOfPages(f"need {n}, have {self.n_free}")
        pages = [self._pop_free(rid) for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self._owned.setdefault(rid, []).extend(pages)
        self._consume(rid, n)
        return pages

    def share(self, rid: int, pages: List[int]) -> None:
        """Map cache-hit ``pages`` into ``rid``'s table (refcount += 1),
        reviving any that were parked reclaimable.  Must be called before
        any further ``alloc`` so a hit can't be reclaimed out from under
        the request that just matched it."""
        for p in pages:
            refs = self._ref.get(p, 0)
            if refs == 0:
                if self.cache is None:
                    raise RuntimeError(
                        f"share() got unreferenced page {p} with no prefix "
                        "cache attached: only reclaimable cached pages can "
                        "be revived")
                self.cache.on_revive(p)
                self._consume(rid)   # a revive takes reclaimable capacity
            self._ref[p] = refs + 1
        self._owned.setdefault(rid, []).extend(pages)
        self.n_shared_maps += len(pages)

    def extend_to(self, rid: int, n_tokens: int) -> List[int]:
        """Ensure rid owns enough pages for n_tokens; returns new pages."""
        have = len(self._owned.get(rid, []))
        need = self.pages_needed(n_tokens) - have
        if need <= 0:
            return []
        return self.alloc(rid, need)

    def prepare_write(self, rid: int, pos: int, n_tokens: int = 1
                      ) -> List[Tuple[int, int]]:
        """Copy-on-write every owned page that tokens [pos, pos+n) will
        scatter into and that is shared (ref > 1) or cached: the writer
        gets a private copy, the original keeps serving its other
        readers / future cache hits.  Returns (src, dst) page pairs whose
        device contents the engine must copy before dispatching.

        On today's engine paths this never fires — cached spans are
        capped below the first written position — but it is what makes
        shared pages safe by construction rather than by convention.
        """
        pages = self._owned.get(rid, [])
        ps = self.page_size
        pairs: List[Tuple[int, int]] = []
        lo = pos // ps
        hi = min((pos + n_tokens - 1) // ps, len(pages) - 1)
        for idx in range(lo, hi + 1):
            p = pages[idx]
            if self._ref.get(p, 0) <= 1 and not \
                    (self.cache is not None and self.cache.is_cached(p)):
                continue
            new = self._pop_free(rid)
            self._ref[new] = 1
            pages[idx] = new
            self._release_one(p)
            pairs.append((p, new))
            self._consume(rid)
            self.n_cow += 1
            self._event("cow", rid=rid, src=p, dst=new)
        return pairs

    def cow_partial(self, rid: int, src: int) -> Tuple[int, int]:
        """Token-level prefix reuse: map a private copy of cached page
        ``src`` into ``rid``'s table as its next page.

        The donor page cannot be shared in place — the request's own
        suffix diverges inside it — so it is referenced first (a
        reclaimable donor is revived, protecting it from being stripped
        while the copy is prepared) and then routed through the standard
        ``prepare_write`` copy-on-write, which restores the donor's
        refcount (a zero-ref donor parks reclaimable again) and hands
        ``rid`` a private page.  Returns the ``(src, dst)`` pair whose
        device contents the engine must copy before prefilling the
        uncached remainder of the page.
        """
        self.share(rid, [src])
        idx = len(self._owned[rid]) - 1
        pairs = self.prepare_write(rid, idx * self.page_size, 1)
        if len(pairs) != 1 or pairs[0][0] != src:
            raise RuntimeError(
                f"cow_partial: expected exactly one copy-on-write pair for "
                f"donor page {src}, got {pairs}; the freshly shared donor "
                "must be the page prepare_write copies")
        self.n_partial_cow += 1
        return pairs[0]

    def owned(self, rid: int) -> List[int]:
        return self._owned.get(rid, [])

    def _release_one(self, page: int) -> bool:
        """Decref; returns True when the page actually left the request's
        hold on capacity (refcount hit zero)."""
        self._ref[page] -= 1
        if self._ref[page] > 0:
            return False
        del self._ref[page]
        if self.cache is not None and self.cache.is_cached(page):
            self.cache.on_release(page)     # park reclaimable, not free
        else:
            self._free.append(page)
            if self.cache is not None:
                self.cache.orphaned_shared.discard(page)
            # int8 scale-sidecar upkeep: the entry dies with the page
            # (engine drops it; the event is NOT a scheduler-trace entry)
            self._event("page_free", page=page)
        return True

    def free(self, rid: int) -> int:
        """Release every page ``rid`` maps; returns how many actually
        became available (shared pages only decref — they stay with
        their other readers)."""
        pages = self._owned.pop(rid, [])
        self._consumed.pop(rid, None)
        return sum(self._release_one(p) for p in reversed(pages))
