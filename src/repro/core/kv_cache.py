"""Host-side paged KV-cache bookkeeping.

The device-side page pool is a plain array [L, N, ps, KV_p, hd] owned by
the engine; this module owns the allocator + per-request block tables —
the paper's "mapping between the inference request ... and the generated
KV-cache file" (§II-G), solved with block tables instead of files.

Page N-1 is reserved as the trash page (inactive batch slots scatter
there); it is never allocated.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


class OutOfPages(Exception):
    pass


@dataclass
class PageAllocator:
    n_pages: int
    page_size: int
    _free: List[int] = field(default_factory=list)
    _owned: Dict[int, List[int]] = field(default_factory=dict)  # rid -> pages

    def __post_init__(self):
        # last page reserved as trash
        self._free = list(range(self.n_pages - 2, -1, -1))

    @property
    def trash_page(self) -> int:
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def usage(self) -> float:
        """KV-cache usage fraction (the paper's Fig. 5/14/15 metric)."""
        return self.n_allocated / (self.n_pages - 1)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, rid: int, n: int) -> List[int]:
        if len(self._free) < n:
            raise OutOfPages(f"need {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(rid, []).extend(pages)
        return pages

    def extend_to(self, rid: int, n_tokens: int) -> List[int]:
        """Ensure rid owns enough pages for n_tokens; returns new pages."""
        have = len(self._owned.get(rid, []))
        need = self.pages_needed(n_tokens) - have
        if need <= 0:
            return []
        return self.alloc(rid, need)

    def owned(self, rid: int) -> List[int]:
        return self._owned.get(rid, [])

    def free(self, rid: int) -> int:
        pages = self._owned.pop(rid, [])
        self._free.extend(reversed(pages))
        return len(pages)
