"""Scheduling mechanism for the serving engine (policies live in
``core/policies.py``).

The paper's premise is serving under *constrained resources*: its
Fig. 5/14/15 analysis shows KV-cache usage climbing toward exhaustion as
batch size grows.  The seed engine simply crashed there — admission
reserved pages for ``len(prompt)+1`` tokens while decode kept allocating
a page every ``page_size`` generated tokens, so ``PageAllocator.extend_to``
eventually raised :class:`OutOfPages` from the decode path.

This module keeps the *mechanism* of page-pressure scheduling — budgets,
eligibility, queue surgery, event tracing — while every *decision* is a
pluggable :mod:`repro.core.policies` object chosen by ``ServeConfig``:

Admission (watermark-based, ``max_new_tokens``-aware)
    A waiting request is admitted only when the pool keeps a
    ``serve.watermark`` fraction free *after* reserving pages for its
    prompt plus ``serve.decode_reserve`` of its remaining generation
    budget.  Which request is *considered* next is the
    ``AdmissionPolicy``'s call: ``fcfs`` walks the queue in arrival
    order; ``cache_aware`` co-schedules resident prefixes first and
    holds a request whose prefix an in-flight prefill is about to cache
    (it waits one round and remaps instead of double-missing), with an
    age-weighted score (``serve.admission_age_weight`` per passed-over
    round, tracked here in ``wait_rounds``) so cold-prefix requests
    cannot starve behind a hot-template stream.
    Head-of-line progress guarantee: when nothing holds pages, the first
    considered request is admitted whenever its bare prompt fits — and
    if even that exceeds the pool, :class:`OutOfPages` is raised eagerly
    with a sizing message instead of mid-decode.

Preemption by recomputation
    When a page extension would exhaust the pool, the ``PreemptPolicy``
    picks a victim among the running requests strictly younger than the
    needy one (eligibility — and with it the termination argument — is
    mechanism, not policy): its pages are freed and the request is
    requeued at the front of the waiting queue.  On re-admission it
    prefills ``prompt + out_tokens`` so greedy decoding resumes exactly
    where it stopped.  ``latest`` evicts the latest arrival;
    ``cache_aware`` prefers victims whose committed KV survives their
    own eviction (pages shared with live requests — resume is a remap,
    not a recompute), tie-broken by latest arrival.  Arrival order still
    bounds every choice — the oldest running request always makes
    progress — so any workload whose requests individually fit the pool
    terminates.  ``preempt_policy == "none"`` restores the seed
    crash-on-exhaustion behaviour (used by benchmarks to show the
    graceful-degradation delta).

Every decision is recorded in ``EngineMetrics.sched_events`` (a capped
ring — ``serve.sched_events_cap``) and aggregated by
``EngineMetrics.summary()`` so benchmarks can plot graceful-degradation
curves; policy-specific counters land in ``EngineMetrics.policy_counters``.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.core.kv_cache import OutOfPages
from repro.core.policies import make_admission, make_preempt
from repro.core.slo import request_footprint


class Scheduler:
    """Owns every admission and page-pressure decision for one Engine.

    The engine keeps the mechanism (batch assembly, jit dispatch, block
    tables); the scheduler keeps the budgets and eligibility rules and
    delegates each choice to its policy objects.  It reads/writes the
    engine's ``slots`` / ``streams`` lists directly when evicting.
    """

    def __init__(self, engine):
        self.eng = engine
        self.serve = engine.serve
        self.admission = make_admission(self.serve.admission_policy)
        self.preempt_pol = make_preempt(self.serve.preempt_policy)  # None =
        self.alloc = engine.alloc                                   # disabled
        self.metrics = engine.metrics
        self.waiting: Deque = deque()
        self._round_probes: dict = {}   # rid -> cache_probe, one round only
        # rid -> admission rounds the request has been passed over; feeds
        # cache_aware aging (serve.admission_age_weight) so a cold-prefix
        # request cannot starve behind a hot-template stream
        self._wait_rounds: dict = {}
        # requests admitted earlier in the CURRENT round: not yet placed
        # in slots/streams by the engine, but already holding quota —
        # tenant_inflight_tokens must see them or a burst could blow
        # through its tenant quota within a single round
        self._round_admits: List = []

    def probe(self, req) -> Tuple[int, int, int]:
        """``Engine.cache_probe`` memoized for the current admission
        round (the trie and page references don't change mid-round, and
        policy ordering, hold checks and budgeting would otherwise each
        repeat the same walk per candidate)."""
        hit = self._round_probes.get(req.rid)
        if hit is None:
            hit = self._round_probes[req.rid] = self.eng.cache_probe(req)
        return hit

    def wait_rounds(self, rid: int) -> int:
        """Admission rounds ``rid`` has been passed over while waiting
        (reset on admission) — the age signal policies weight against
        resident-prefix advantage."""
        return self._wait_rounds.get(rid, 0)

    def tenant_inflight_tokens(self, tenant: str) -> int:
        """Footprint tokens (prompt + full generation grant,
        ``core/slo.py``) `tenant` currently holds in flight: requests
        occupying decode slots or prefill streams, plus this round's
        earlier admits (not yet placed by the engine).  The quantity
        ``DeadlineAdmission.holds`` charges quotas against and the
        ``tenant_quota`` sanitizer invariant re-derives."""
        seen: set = set()
        total = 0
        for cont in (self.eng.slots, self.eng.streams):
            for s in cont:
                if s is None or s.req.rid in seen:
                    continue
                seen.add(s.req.rid)
                if self.eng.effective_slo(s.req).tenant == tenant:
                    total += request_footprint(s.req)
        for r in self._round_admits:
            if r.rid not in seen:
                seen.add(r.rid)
                if self.eng.effective_slo(r).tenant == tenant:
                    total += request_footprint(r)
        return total

    # ------------------------------------------------------------ queue ----
    def submit(self, req) -> None:
        self.waiting.append(req)

    def requeue(self, req) -> None:
        """Put a preempted request at the *front* so it resumes first."""
        self.waiting.appendleft(req)

    # -------------------------------------------------------- admission ----
    @property
    def watermark_pages(self) -> int:
        return int(math.ceil(self.serve.watermark * (self.alloc.n_pages - 1)))

    def admission_pages(self, req, free_cached: int = 0,
                        cow_extra: int = 0, n_hit: int = 0) -> int:
        """Pages to budget for admitting `req`: prompt (plus any tokens
        generated before a preemption) + 1, plus `decode_reserve` of the
        remaining generation as decode headroom.  The generation budget
        is per-request (``req.sampling.max_new_tokens``), so a mixed
        queue of short and long requests is budgeted request by request.

        With the prefix cache enabled, only the *miss* pages are
        budgeted: ``free_cached`` (live-referenced hit pages, from
        ``Engine.cache_probe``) don't come out of the free pool, while
        reclaimable hits are charged like fresh allocs — reviving them
        consumes free capacity.  ``cow_extra`` charges the transient
        page a token-level partial hit holds while its unreferenced
        donor is revived for the COW copy (the copy's destination page
        is already inside ``pages_needed``; the donor returns to the
        reclaimable pool once the copy exists).

        In ``mode="chunked"`` admission budgets *per-chunk* pages
        instead of the whole prompt: the cached prefix (``n_hit`` full
        pages — mapped in their entirety at admission) plus ONE planner
        chunk (``serve.chunk_tokens``).  Later chunks pre-commit their
        pages as the planner schedules them (``Engine._compose_prefill``
        → ``KVSanitizer.note_chunk``), so a long prompt stops reserving
        the pool up front and admission interleaves with in-flight
        prefills.
        """
        remaining = max(req.sampling.max_new_tokens - len(req.out_tokens), 1)
        headroom = int(self.serve.decode_reserve * (remaining - 1))
        n_prefill = len(req.prompt) + len(req.out_tokens)
        if self.serve.mode == "chunked":
            n_prefill = min(n_prefill, n_hit * self.alloc.page_size
                            + self.serve.chunk_tokens)
        need = self.alloc.pages_needed(n_prefill + 1 + headroom)
        return max(need - free_cached, 0) + cow_extra

    def _bare_pages(self, req) -> int:
        """Minimum pages the request needs to start; raises if the pool
        or a block-table row can never hold it (clear sizing error
        instead of a decode-path crash)."""
        n_prefill = len(req.prompt) + len(req.out_tokens)
        need = self.alloc.pages_needed(n_prefill + 1)
        if need > self.alloc.n_pages - 1:
            raise OutOfPages(
                f"request {req.rid} needs {need} pages for "
                f"{n_prefill} tokens but the pool only has "
                f"{self.alloc.n_pages - 1}; raise n_pages/page_size")
        if need > self.serve.max_pages_per_seq:
            raise OutOfPages(
                f"request {req.rid} needs {need} pages for "
                f"{n_prefill} tokens but block tables hold "
                f"{self.serve.max_pages_per_seq}; raise max_pages_per_seq")
        return need

    def _try_admit(self, r, budget: int, first: bool) -> Tuple[bool, int]:
        """Admit `r` (removing it from the waiting queue) if it fits
        `budget`.  Progress override: when the pool is completely idle
        and this would be the round's first admission, the request is
        admitted on a bare-prompt fit even if the watermark/headroom
        budget says no (otherwise a big request could wait forever
        behind its own reservation)."""
        bare = self._bare_pages(r)      # raises when it can never fit
        n_hit, n_free_hit, cow_extra = self.probe(r)
        need = self.admission_pages(r, n_free_hit, cow_extra, n_hit)
        override = False
        if need > budget:
            if not (first and self.alloc.n_allocated == 0):
                return False, budget
            need = bare
            override = True
        self.waiting.remove(r)
        self._wait_rounds.pop(r.rid, None)
        self._round_admits.append(r)
        self.alloc.begin_admission(r.rid)
        self.eng.register_inflight(r)
        if self.eng.sanitizer is not None:
            self.eng.sanitizer.note_admit(r.rid, need, override)
        self._event("admit", r.rid, pages=need, cached_pages=n_hit,
                    resumed=bool(r.out_tokens), override=override)
        return True, budget - need

    def _admit_up_to(self, limit: int) -> List:
        """One admission round: the policy orders (and may hold back)
        the waiting queue; the budget walk stops at the first candidate
        that doesn't fit (head-of-line blocking within the policy's
        order, which for ``fcfs`` is exactly the seed behaviour)."""
        out: List = []
        if limit <= 0 or not self.waiting:
            return out      # no round: skip policy ordering (and its
                            # trie walks / reorder-hold counters) entirely
        budget = self.alloc.n_free - self.watermark_pages
        self._round_probes = {}
        self._round_admits = []
        for r in self.admission.order(self):
            if len(out) >= limit:
                break
            if self.admission.holds(self, r):
                continue        # skipped this round, not a budget block
            ok, budget = self._try_admit(r, budget, first=not out)
            if not ok:
                break
            out.append(r)
        for r in self.waiting:          # passed over this round: age them
            self._wait_rounds[r.rid] = self._wait_rounds.get(r.rid, 0) + 1
        return out

    def take_prefillable(self) -> List:
        """Sequential-mode admission: requests that fit the free decode
        slots and the watermarked page budget, in policy order."""
        return self._admit_up_to(sum(s is None for s in self.eng.slots))

    def admit_streams(self) -> List:
        """Splitwiser-mode admission: requests to place on free prefill
        streams under the same watermarked budget."""
        return self._admit_up_to(sum(s is None for s in self.eng.streams))

    # -------------------------------------------------------- preemption ---
    def ensure_pages(self, req, n_tokens: int, protect=()) -> bool:
        """Make the allocator able to extend `req` to `n_tokens`,
        evicting victims chosen by the preempt policy.

        Returns False when only older requests (or `protect`-ed ones)
        hold the remaining pages — the caller yields (self-preempts or
        skips its chunk).  Raises OutOfPages when the sequence alone can
        never fit the pool or its block-table row.
        """
        if self.alloc.pages_needed(n_tokens) > self.serve.max_pages_per_seq:
            raise OutOfPages(
                f"request {req.rid} at {n_tokens} tokens needs "
                f"{self.alloc.pages_needed(n_tokens)} pages but block tables "
                f"hold {self.serve.max_pages_per_seq}; raise max_pages_per_seq")
        need = self.alloc.pages_needed(n_tokens) - len(self.alloc.owned(req.rid))
        if need <= 0 or self.alloc.can_alloc(need):
            return True
        if self.preempt_pol is not None:
            while not self.alloc.can_alloc(need):
                victim = self._pick_victim(req, protect)
                if victim is None:
                    break
                self.preempt(*victim, reason=f"pressure rid={req.rid}")
            if self.alloc.can_alloc(need):
                return True
        if self.alloc.n_allocated == len(self.alloc.owned(req.rid)):
            raise OutOfPages(
                f"request {req.rid} needs {self.alloc.pages_needed(n_tokens)} "
                f"pages at {n_tokens} tokens but the pool only has "
                f"{self.alloc.n_pages - 1}; raise n_pages/page_size")
        return False

    def _victim_candidates(self, needy, protect=()) -> List[Tuple]:
        """Eligible victims: running requests strictly younger than
        `needy` (arrival order stays a total priority order — the
        termination guarantee is mechanism, not policy) whose eviction
        would actually free capacity.  Rows are
        ``(kind, index, req, committed_tokens)``."""
        cands: List[Tuple] = []
        for kind, cont in (("slot", self.eng.slots),
                           ("stream", self.eng.streams)):
            for i, s in enumerate(cont):
                if s is None or s.req.rid in protect:
                    continue
                if not self.alloc.n_exclusive(s.req.rid):
                    continue     # page-less, or every page shared with a
                                 # live reader: evicting frees nothing
                if (s.req.arrival, s.req.rid) <= (needy.arrival, needy.rid):
                    continue
                committed = s.seq_len if kind == "slot" else s.pos
                cands.append((kind, i, s.req, committed))
        return cands

    def _pick_victim(self, needy, protect=()) -> Optional[Tuple[str, int]]:
        return self.preempt_pol.select(
            self._victim_candidates(needy, protect), self.eng)

    def preempt(self, kind: str, index: int, reason: str = "") -> None:
        """Evict a running request: free its pages and requeue it with
        its generated tokens folded into the next prefill (recomputation
        — paper §II-G's KV "mapping" is simply rebuilt)."""
        cont = self.eng.slots if kind == "slot" else self.eng.streams
        victim = cont[index]
        cont[index] = None
        r = victim.req
        # register the victim's committed KV with the prefix cache BEFORE
        # freeing: its pages park reclaimable and the resume re-hits them
        # (recomputation becomes a cheap remap unless pressure reclaimed
        # them in the meantime)
        committed = victim.seq_len if kind == "slot" else victim.pos
        self.eng.cache_insert(r, committed, final=True)
        self.eng.unregister_inflight(r.rid)
        if self.eng.sanitizer is not None:
            # re-admission re-budgets; the sanitizer also snapshots the
            # resume_safe_pages promise here (before free drops the
            # victim's refs) and settles it when the resume re-maps
            self.eng.sanitizer.note_preempt(r, committed)
        freed = self.alloc.free(r.rid)
        self.requeue(r)
        self.metrics.req(r.rid).n_preempted += 1
        self.metrics.n_preempt_events += 1
        self._event("preempt", r.rid, kind=kind, pages=freed, reason=reason)

    # ------------------------------------------------------------ trace ----
    def _event(self, ev: str, rid: int, **detail) -> None:
        self.metrics.sched_events.append(
            {"t": self.eng.now(), "event": ev, "rid": rid, **detail})
