"""Admission control and preemption for the serving engine.

The paper's premise is serving under *constrained resources*: its
Fig. 5/14/15 analysis shows KV-cache usage climbing toward exhaustion as
batch size grows.  The seed engine simply crashed there — admission
reserved pages for ``len(prompt)+1`` tokens while decode kept allocating
a page every ``page_size`` generated tokens, so ``PageAllocator.extend_to``
eventually raised :class:`OutOfPages` from the decode path.

This module makes page pressure a first-class scheduling concern (the
subsystem vLLM and SARATHI-style single-GPU schedulers treat as such):

Admission (watermark-based, ``max_new_tokens``-aware)
    A waiting request is admitted only when the pool keeps a
    ``serve.watermark`` fraction free *after* reserving pages for its
    prompt plus ``serve.decode_reserve`` of its remaining generation
    budget.  Head-of-line progress guarantee: when nothing holds pages,
    the head request is admitted whenever its bare prompt fits — and if
    even that exceeds the pool, :class:`OutOfPages` is raised eagerly
    with a sizing message instead of mid-decode.

Preemption by recomputation (``serve.preempt_policy == "latest"``)
    When a page extension would exhaust the pool, the running request
    (decode slot or prefill stream) with the *latest* arrival among
    those younger than the needy one is evicted: its pages are freed and
    the request is requeued at the front of the waiting queue.  On
    re-admission it prefills ``prompt + out_tokens`` so greedy decoding
    resumes exactly where it stopped.  Arrival order gives a total
    priority order — the oldest running request always makes progress —
    so any workload whose requests individually fit the pool terminates.
    ``preempt_policy == "none"`` restores the seed crash-on-exhaustion
    behaviour (used by benchmarks to show the graceful-degradation
    delta).

Every decision is recorded in ``EngineMetrics.sched_events`` and
aggregated by ``EngineMetrics.summary()`` so benchmarks can plot
graceful-degradation curves.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.core.kv_cache import OutOfPages


class Scheduler:
    """Owns every admission and page-pressure decision for one Engine.

    The engine keeps the mechanism (batch assembly, jit dispatch, block
    tables); the scheduler keeps the policy.  It reads/writes the
    engine's ``slots`` / ``streams`` lists directly when evicting.
    """

    def __init__(self, engine):
        self.eng = engine
        self.serve = engine.serve
        if self.serve.preempt_policy not in ("latest", "none"):
            raise ValueError(
                f"unknown preempt_policy {self.serve.preempt_policy!r}; "
                "expected 'latest' or 'none'")
        self.alloc = engine.alloc
        self.metrics = engine.metrics
        self.waiting: Deque = deque()

    # ------------------------------------------------------------ queue ----
    def submit(self, req) -> None:
        self.waiting.append(req)

    def requeue(self, req) -> None:
        """Put a preempted request at the *front* so it resumes first."""
        self.waiting.appendleft(req)

    # -------------------------------------------------------- admission ----
    @property
    def watermark_pages(self) -> int:
        return int(math.ceil(self.serve.watermark * (self.alloc.n_pages - 1)))

    def admission_pages(self, req, free_cached: int = 0) -> int:
        """Pages to budget for admitting `req`: prompt (plus any tokens
        generated before a preemption) + 1, plus `decode_reserve` of the
        remaining generation as decode headroom.  The generation budget
        is per-request (``req.sampling.max_new_tokens``), so a mixed
        queue of short and long requests is budgeted request by request.

        With the prefix cache enabled, only the *miss* pages are
        budgeted: ``free_cached`` (live-referenced hit pages, from
        ``Engine.cache_probe``) don't come out of the free pool, while
        reclaimable hits are charged like fresh allocs — reviving them
        consumes free capacity.
        """
        remaining = max(req.sampling.max_new_tokens - len(req.out_tokens), 1)
        headroom = int(self.serve.decode_reserve * (remaining - 1))
        n_prefill = len(req.prompt) + len(req.out_tokens)
        need = self.alloc.pages_needed(n_prefill + 1 + headroom)
        return max(need - free_cached, 0)

    def _bare_pages(self, req) -> int:
        """Minimum pages the request needs to start; raises if the pool
        or a block-table row can never hold it (clear sizing error
        instead of a decode-path crash)."""
        n_prefill = len(req.prompt) + len(req.out_tokens)
        need = self.alloc.pages_needed(n_prefill + 1)
        if need > self.alloc.n_pages - 1:
            raise OutOfPages(
                f"request {req.rid} needs {need} pages for "
                f"{n_prefill} tokens but the pool only has "
                f"{self.alloc.n_pages - 1}; raise n_pages/page_size")
        if need > self.serve.max_pages_per_seq:
            raise OutOfPages(
                f"request {req.rid} needs {need} pages for "
                f"{n_prefill} tokens but block tables hold "
                f"{self.serve.max_pages_per_seq}; raise max_pages_per_seq")
        return need

    def _admit_head(self, budget: int, first: bool) -> Tuple[Optional[object], int]:
        """Pop the head request if it fits `budget`.  Progress override:
        when the pool is completely idle and this would be the first
        admission, the head is admitted on a bare-prompt fit even if the
        watermark/headroom budget says no (otherwise a big request could
        wait forever behind its own reservation)."""
        r = self.waiting[0]
        bare = self._bare_pages(r)      # raises when it can never fit
        n_hit, n_free_hit = self.eng.cache_probe(r)   # one trie walk
        need = self.admission_pages(r, n_free_hit)
        if need > budget:
            if not (first and self.alloc.n_allocated == 0):
                return None, budget
            need = bare
        self.waiting.popleft()
        self._event("admit", r.rid, pages=need, cached_pages=n_hit,
                    resumed=bool(r.out_tokens))
        return r, budget - need

    def _admit_up_to(self, limit: int) -> List:
        out: List = []
        budget = self.alloc.n_free - self.watermark_pages
        while self.waiting and len(out) < limit:
            r, budget = self._admit_head(budget, first=not out)
            if r is None:
                break
            out.append(r)
        return out

    def take_prefillable(self) -> List:
        """Sequential-mode admission: head-of-queue requests that fit the
        free decode slots and the watermarked page budget."""
        return self._admit_up_to(sum(s is None for s in self.eng.slots))

    def admit_streams(self) -> List:
        """Splitwiser-mode admission: requests to place on free prefill
        streams under the same watermarked budget."""
        return self._admit_up_to(sum(s is None for s in self.eng.streams))

    # -------------------------------------------------------- preemption ---
    def ensure_pages(self, req, n_tokens: int, protect=()) -> bool:
        """Make the allocator able to extend `req` to `n_tokens`,
        evicting younger victims under the "latest" policy.

        Returns False when only older requests (or `protect`-ed ones)
        hold the remaining pages — the caller yields (self-preempts or
        skips its chunk).  Raises OutOfPages when the sequence alone can
        never fit the pool or its block-table row.
        """
        if self.alloc.pages_needed(n_tokens) > self.serve.max_pages_per_seq:
            raise OutOfPages(
                f"request {req.rid} at {n_tokens} tokens needs "
                f"{self.alloc.pages_needed(n_tokens)} pages but block tables "
                f"hold {self.serve.max_pages_per_seq}; raise max_pages_per_seq")
        need = self.alloc.pages_needed(n_tokens) - len(self.alloc.owned(req.rid))
        if need <= 0 or self.alloc.can_alloc(need):
            return True
        if self.serve.preempt_policy == "latest":
            while not self.alloc.can_alloc(need):
                victim = self._pick_victim(req, protect)
                if victim is None:
                    break
                self.preempt(*victim, reason=f"pressure rid={req.rid}")
            if self.alloc.can_alloc(need):
                return True
        if self.alloc.n_allocated == len(self.alloc.owned(req.rid)):
            raise OutOfPages(
                f"request {req.rid} needs {self.alloc.pages_needed(n_tokens)} "
                f"pages at {n_tokens} tokens but the pool only has "
                f"{self.alloc.n_pages - 1}; raise n_pages/page_size")
        return False

    def _pick_victim(self, needy, protect=()) -> Optional[Tuple[str, int]]:
        """Latest-arrival running request strictly younger than `needy`."""
        best_key, best = None, None
        for kind, cont in (("slot", self.eng.slots),
                           ("stream", self.eng.streams)):
            for i, s in enumerate(cont):
                if s is None or s.req.rid in protect:
                    continue
                if not self.alloc.n_exclusive(s.req.rid):
                    continue     # page-less, or every page shared with a
                                 # live reader: evicting frees nothing
                key = (s.req.arrival, s.req.rid)
                if key <= (needy.arrival, needy.rid):
                    continue
                if best_key is None or key > best_key:
                    best_key, best = key, (kind, i)
        return best

    def preempt(self, kind: str, index: int, reason: str = "") -> None:
        """Evict a running request: free its pages and requeue it with
        its generated tokens folded into the next prefill (recomputation
        — paper §II-G's KV "mapping" is simply rebuilt)."""
        cont = self.eng.slots if kind == "slot" else self.eng.streams
        victim = cont[index]
        cont[index] = None
        r = victim.req
        # register the victim's committed KV with the prefix cache BEFORE
        # freeing: its pages park reclaimable and the resume re-hits them
        # (recomputation becomes a cheap remap unless pressure reclaimed
        # them in the meantime)
        committed = victim.seq_len if kind == "slot" else victim.pos
        self.eng.cache_insert(r, committed)
        freed = self.alloc.free(r.rid)
        self.requeue(r)
        self.metrics.req(r.rid).n_preempted += 1
        self._event("preempt", r.rid, kind=kind, pages=freed, reason=reason)

    # ------------------------------------------------------------ trace ----
    def _event(self, ev: str, rid: int, **detail) -> None:
        self.metrics.sched_events.append(
            {"t": self.eng.now(), "event": ev, "rid": rid, **detail})
