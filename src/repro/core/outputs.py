"""Streaming results of the serving engine (vLLM-shaped).

``Engine.step()`` returns the step's :class:`TokenEvent` list,
``Engine.stream()`` yields them as they happen, and ``Engine.poll()``
drains the :class:`RequestOutput` of every request finished since the
last poll.  Events and outputs carry virtual-clock timestamps so
open-loop benchmarks read TTFT/TBT straight off the stream.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class TokenEvent:
    """One generated token, as observed on the stream."""
    rid: int
    token: int
    index: int                    # position in the request's output (0-based)
    t: float                      # engine-clock timestamp of emission
    first: bool                   # True for the request's very first token
    finish_reason: Optional[str] = None   # "length" | "stop" on the last token


@dataclass
class RequestOutput:
    """Final result of one request, drained via ``Engine.poll()``."""
    rid: int
    prompt: List[int]
    tokens: List[int]
    finish_reason: str            # "length" (budget) | "stop" (eos/stop token)
    n_preempted: int              # times evicted + resumed before finishing
    n_cached_tokens: int          # prefill tokens served by the prefix cache
    arrival: float
    token_times: List[float] = field(default_factory=list)
    t_done: float = 0.0
    tenant: str = "default"       # SLOParams.tenant (core/slo.py)
    # SLO verdict settled at finish: True/False for deadline-carrying
    # requests, None when no TTFT/TBT target resolved for it
    slo_attained: Optional[bool] = None

    @property
    def ttft(self) -> Optional[float]:
        return None if not self.token_times else self.token_times[0] - self.arrival

    @property
    def e2e(self) -> float:
        return self.t_done - self.arrival

    @property
    def tbt(self) -> Optional[float]:
        if len(self.token_times) < 2:
            return None
        gaps = [b - a for a, b in zip(self.token_times, self.token_times[1:],
                                      strict=False)]
        return sum(gaps) / len(gaps)
