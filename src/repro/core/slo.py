"""Per-request SLO surface: deadlines, tenants, and their resolution.

Splitwiser's phase split exists to serve latency-sensitive traffic on
constrained hardware, but the policy layer (``core/policies.py``) only
learned to price cache hits and occupancy — nothing knew what a request's
*deadline* was, so one tenant's burst could legally destroy another's
p99.  This module gives every request that vocabulary:

:class:`SLOParams`
    Travels with each :class:`~repro.core.engine.Request` (like
    ``SamplingParams``): optional ``ttft_target`` / ``tbt_target``
    deadlines on the engine's virtual clock, and a ``tenant`` id.

:class:`~repro.configs.base.TenantTier` (``ServeConfig.tenants``)
    Per-tenant tier defaults: targets a request inherits when its own
    ``SLOParams`` leaves them unset, an in-flight token ``quota_tokens``
    (the fairness lever: a tenant's burst queues behind its quota instead
    of starving everyone else), and a ``weight`` the chunk planner's
    carve order respects.

:func:`resolve_slo`
    Request-over-tier resolution into one :class:`EffectiveSLO` view —
    the single lookup the ``deadline`` policies, the chunk planner, the
    SLO metrics rollup, and the quota-honesty sanitizer check all share,
    so "what does this request owe and to whom" has exactly one answer.

Deadline semantics (all on the engine clock, virtual or wall):

* TTFT deadline  = ``arrival + ttft_target`` — binds until the first
  token is emitted;
* TBT deadline   = ``last_token_time + tbt_target`` — binds between
  consecutive tokens; a finished request attains its TBT target iff its
  *worst* inter-token gap met it.

A request with neither target resolved carries no deadline: its slack is
infinite, every ``deadline`` policy degenerates to the FCFS/latest
behaviour around it, and it is excluded from SLO-attainment fractions.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping, NamedTuple, Optional

from repro.configs.base import TenantTier

DEFAULT_TENANT = "default"


@dataclass(frozen=True)
class SLOParams:
    """Per-request service-level objectives (``Request.slo``).

    ``ttft_target`` / ``tbt_target`` are deadlines in engine-clock
    seconds (virtual seconds under a counting/work clock); ``None``
    inherits the request's tenant tier, and "no target anywhere" means
    the request carries no deadline at all.  ``tenant`` names the
    :class:`~repro.configs.base.TenantTier` in ``ServeConfig.tenants``
    that supplies defaults, the in-flight token quota, and the planner
    weight ("default" when the operator configured no tiers).
    """
    ttft_target: Optional[float] = None
    tbt_target: Optional[float] = None
    tenant: str = DEFAULT_TENANT

    def __post_init__(self):
        for knob in ("ttft_target", "tbt_target"):
            value = getattr(self, knob)
            if value is not None and (
                    not isinstance(value, (int, float))
                    or isinstance(value, bool) or value <= 0):
                raise ValueError(
                    f"{knob} must be a positive number of engine-clock "
                    f"seconds or None, got {value!r}")
        if not isinstance(self.tenant, str) or not self.tenant:
            raise ValueError(
                f"tenant must be a non-empty string, got {self.tenant!r}")

    @property
    def has_target(self) -> bool:
        return self.ttft_target is not None or self.tbt_target is not None


class EffectiveSLO(NamedTuple):
    """A request's SLO after tier resolution (request overrides tier)."""
    tenant: str
    ttft_target: Optional[float]
    tbt_target: Optional[float]
    quota_tokens: Optional[int]     # tenant in-flight token quota (tier-only)
    weight: float                   # planner carve-order weight (tier-only)

    @property
    def has_deadline(self) -> bool:
        return self.ttft_target is not None or self.tbt_target is not None


_NO_SLO = EffectiveSLO(DEFAULT_TENANT, None, None, None, 1.0)


def resolve_slo(slo: Optional[SLOParams],
                tiers: Mapping[str, TenantTier]) -> EffectiveSLO:
    """Resolve a request's effective SLO: per-request targets win, unset
    ones fall back to the tenant's tier (when one is configured), quota
    and weight always come from the tier (they are tenant-scoped, not
    request-scoped)."""
    if slo is None:
        slo = SLOParams()
    tier = tiers.get(slo.tenant)
    if tier is None:
        if slo.tenant == DEFAULT_TENANT and not slo.has_target:
            return _NO_SLO
        return EffectiveSLO(slo.tenant, slo.ttft_target, slo.tbt_target,
                            None, 1.0)
    return EffectiveSLO(
        slo.tenant,
        slo.ttft_target if slo.ttft_target is not None else tier.ttft_target,
        slo.tbt_target if slo.tbt_target is not None else tier.tbt_target,
        tier.quota_tokens,
        tier.weight)


def request_footprint(req) -> int:
    """Token footprint a request charges against its tenant's in-flight
    quota: prompt plus full generation budget.  Deliberately the *grant*
    (``max_new_tokens``), not current progress — quotas bound what a
    tenant may hold concurrently, and a burst of long-budget requests
    reserves the pool whether or not the tokens exist yet."""
    return len(req.prompt) + req.sampling.max_new_tokens


def ttft_slack(req, eff: EffectiveSLO, now: float) -> float:
    """Seconds of TTFT slack at ``now`` (``inf`` when no TTFT target):
    how long admission can still defer this request before its first
    token is late."""
    if eff.ttft_target is None:
        return math.inf
    return (req.arrival or 0.0) + eff.ttft_target - now


def slo_outcome(ttft: Optional[float], worst_gap: Optional[float],
                eff: EffectiveSLO) -> Optional[bool]:
    """Did a finished request attain its SLO?  ``None`` when it carries
    no deadline (excluded from attainment fractions); otherwise every
    resolved target must hold — TTFT against the first-token latency,
    TBT against the *worst* inter-token gap (zero gaps trivially
    attain)."""
    if not eff.has_deadline:
        return None
    if eff.ttft_target is not None and (
            ttft is None or ttft > eff.ttft_target):
        return False
    if eff.tbt_target is not None and (
            worst_gap is not None and worst_gap > eff.tbt_target):
        return False
    return True
