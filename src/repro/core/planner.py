"""SARATHI-style chunked-prefill phase planner (``mode="chunked"``).

The engine's other modes dispatch each phase monolithically: a single
2k-token prompt's prefill stalls every in-flight decode for the whole
prompt — the long-prompt tail-TBT cliff.  The planner inverts the
priority: each round, every runnable decode token claims its slice of a
fixed token budget (``ServeConfig.chunk_tokens``) first — decodes are
never starved — and the *remainder* is carved over the in-flight prefill
streams.  The engine dispatches the resulting :class:`ChunkPlan` as one
mixed program per round, so compute intensity stays flat and no decode
ever waits longer than ~one chunk's worth of prefill work.

The planner is pure bookkeeping: it decides *how many* tokens each
stream contributes this round; the engine keeps page budgeting,
cache fast-forwarding and dispatch.  Streams are served round-robin
from a rotating cursor so a long prompt on stream 0 cannot
permanently crowd out stream 1 when the budget is tight; when the
engine passes per-stream deadline ``priorities`` (tenant-weighted TTFT
slack, ``core/slo.py``), the carve runs most-urgent-first instead, so
a deadline-critical prefill is never the one left holding the bag on a
tight round.

:func:`validate_plan` makes the packing contract executable; the runtime
sanitizer (``analysis/invariants.py``, ``KVSanitizer.note_plan``) runs it
against every live plan at any ``sanitize_level`` above ``off``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class ChunkPlan:
    """One round's mixed-batch packing decision.

    ``chunk_lens[i]`` is the prefill-token count stream ``i`` contributes
    this round (0 for empty or passed-over streams); ``n_decode_tokens``
    is every runnable decode token — packed unconditionally, they are
    what the budget is *for*.  ``cap`` is the static per-stream token
    array width the engine compiles against (== ``budget``, so a single
    stream may absorb the whole budget without a reshape).
    """
    chunk_lens: Tuple[int, ...]
    n_decode_tokens: int
    budget: int          # ServeConfig.chunk_tokens
    cap: int             # static p_tokens row width

    @property
    def n_prefill_tokens(self) -> int:
        return sum(self.chunk_lens)

    @property
    def n_packed_tokens(self) -> int:
        return self.n_prefill_tokens + self.n_decode_tokens

    @property
    def occupancy(self) -> float:
        """Packed tokens over budget; may exceed 1.0 when the decode
        batch alone outgrows ``chunk_tokens`` (decodes are never
        dropped to fit)."""
        return self.n_packed_tokens / self.budget


class ChunkPlanner:
    """Carves in-flight prefills into fixed-token-budget chunks packed
    with the round's decode tokens (one plan per engine round)."""

    def __init__(self, chunk_tokens: int, n_streams: int):
        if chunk_tokens <= 0:
            raise ValueError(
                f"chunk_tokens must be positive, got {chunk_tokens}")
        if n_streams <= 0:
            raise ValueError(f"n_streams must be positive, got {n_streams}")
        self.chunk_tokens = chunk_tokens
        self.n_streams = n_streams
        self._cursor = 0     # round-robin start stream (fairness under
                             # a budget too small for every stream)

    def plan(self, remaining: Sequence[int], n_decode_tokens: int,
             priorities: Optional[Sequence[Optional[float]]] = None
             ) -> ChunkPlan:
        """Pack one round: ``remaining[i]`` prefill tokens left on stream
        ``i`` (0 when empty), ``n_decode_tokens`` runnable decodes.

        Decodes take their budget share first; what's left is carved
        greedily over the streams starting at the rotating cursor.  The
        carve is work-conserving: budget only goes unused when no stream
        has tokens left to take it.

        ``priorities`` makes the carve order deadline/weight-aware
        (``core/slo.py``): when any entry is non-None, streams are
        carved most-urgent first — ascending by priority value
        (weighted TTFT slack as computed by the engine), ``None``
        entries (no deadline) last in stream order — instead of from
        the rotating cursor.  The cursor still advances so dropping
        back to the deadline-free path (all-None rounds) keeps its
        round-robin fairness exactly where it would have been.  Only
        the carve *order* changes; :func:`validate_plan`'s packing
        contract (totals, caps, work conservation) is order-blind, so
        urgency-ordered plans satisfy the same invariant.
        """
        if len(remaining) != self.n_streams:
            raise ValueError(
                f"plan() got {len(remaining)} stream remainders for "
                f"{self.n_streams} streams")
        if n_decode_tokens < 0:
            raise ValueError(
                f"n_decode_tokens must be >= 0, got {n_decode_tokens}")
        if priorities is not None and len(priorities) != self.n_streams:
            raise ValueError(
                f"plan() got {len(priorities)} stream priorities for "
                f"{self.n_streams} streams")
        if priorities is not None and any(p is not None for p in priorities):
            carve = sorted(range(self.n_streams),
                           key=lambda i: (priorities[i] is None,
                                          priorities[i]
                                          if priorities[i] is not None
                                          else 0.0, i))
        else:
            carve = [(self._cursor + k) % self.n_streams
                     for k in range(self.n_streams)]
        lens = [0] * self.n_streams
        left = max(self.chunk_tokens - n_decode_tokens, 0)
        for i in carve:
            if left <= 0:
                break
            take = min(max(remaining[i], 0), left)
            lens[i] = take
            left -= take
        self._cursor = (self._cursor + 1) % self.n_streams
        return ChunkPlan(chunk_lens=tuple(lens),
                         n_decode_tokens=n_decode_tokens,
                         budget=self.chunk_tokens, cap=self.chunk_tokens)


def validate_plan(plan: ChunkPlan, remaining: Sequence[int],
                  n_decode_tokens: int) -> None:
    """Raise ``ValueError`` when ``plan`` breaks the packing contract for
    the round it was made from:

    * every decode token is packed (never dropped or invented);
    * no stream is carved past its remaining tokens or the static cap;
    * total prefill fits the budget the decodes left over;
    * the carve is work-conserving — leftover budget with a stream still
      holding tokens means the planner under-packed the round.
    """
    if len(plan.chunk_lens) != len(remaining):
        raise ValueError(
            f"plan covers {len(plan.chunk_lens)} streams, round has "
            f"{len(remaining)}")
    if plan.n_decode_tokens != n_decode_tokens:
        raise ValueError(
            f"plan packs {plan.n_decode_tokens} decode tokens but the "
            f"round has {n_decode_tokens} runnable decodes: decodes must "
            "be packed unconditionally")
    prefill_budget = max(plan.budget - plan.n_decode_tokens, 0)
    for i, (take, rem) in enumerate(zip(plan.chunk_lens, remaining,
                                        strict=True)):
        if take < 0:
            raise ValueError(f"stream {i}: negative chunk length {take}")
        if take > max(rem, 0):
            raise ValueError(
                f"stream {i}: chunk of {take} tokens exceeds the stream's "
                f"{rem} remaining prefill tokens")
        if take > plan.cap:
            raise ValueError(
                f"stream {i}: chunk of {take} tokens exceeds the static "
                f"row cap {plan.cap}")
    if plan.n_prefill_tokens > prefill_budget:
        raise ValueError(
            f"plan packs {plan.n_prefill_tokens} prefill tokens but "
            f"{plan.n_decode_tokens} decodes leave only {prefill_budget} "
            f"of the {plan.budget}-token budget")
    leftover = prefill_budget - plan.n_prefill_tokens
    if leftover > 0:
        starved = [i for i, (take, rem)
                   in enumerate(zip(plan.chunk_lens, remaining, strict=True))
                   if max(rem, 0) > take]
        if starved:
            raise ValueError(
                f"plan leaves {leftover} budget tokens unused while "
                f"streams {starved} still hold prefill work "
                "(not work-conserving)")
