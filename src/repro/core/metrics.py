"""The paper's performance metrics (§II-E): E2E latency, TTFT, TBT,
throughput, plus KV-cache usage traces (Fig. 5/14/15)."""
from __future__ import annotations

import statistics
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

DEFAULT_SCHED_EVENTS_CAP = 16384


class EventRing:
    """Bounded scheduler-event trace (list-like, oldest-first).

    Long open-loop runs emit an admit/preempt/reclaim event stream that
    previously grew without bound; this ring keeps the newest ``cap``
    events and counts what it dropped (``n_dropped``) so consumers can
    tell a short trace from a truncated one.  Supports the list surface
    existing readers use: iteration, ``len``, indexing and slicing.
    """

    def __init__(self, cap: int = DEFAULT_SCHED_EVENTS_CAP):
        if cap <= 0:
            raise ValueError(f"EventRing cap must be positive, got {cap}")
        self.cap = cap
        self._buf: deque = deque(maxlen=cap)
        self.n_dropped = 0

    def append(self, event: dict) -> None:
        if len(self._buf) == self.cap:
            self.n_dropped += 1
        self._buf.append(event)

    @property
    def n_total(self) -> int:
        """Events ever appended (retained + dropped) — a stable cursor
        for "what arrived since" bookkeeping that survives drops."""
        return len(self._buf) + self.n_dropped

    def __iter__(self) -> Iterator[dict]:
        return iter(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def __bool__(self) -> bool:
        return bool(self._buf)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self._buf)[idx]
        return self._buf[idx]

    def __repr__(self) -> str:
        return (f"EventRing(cap={self.cap}, n={len(self._buf)}, "
                f"dropped={self.n_dropped})")


@dataclass
class RequestMetrics:
    rid: int
    arrival: float = 0.0
    t_prefill_start: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    n_prompt: int = 0
    n_generated: int = 0
    n_preempted: int = 0         # times this request was evicted + requeued
    n_cached_tokens: int = 0     # prefill tokens served from the prefix cache
                                 # (summed across preemption resumes)
    finish_reason: Optional[str] = None   # "length" | "stop" once done
    # --- SLO accounting (core/slo.py; stamped at submit / settled at
    # finish by the engine) ---
    tenant: str = "default"
    ttft_target: Optional[float] = None   # effective (tier-resolved) targets
    tbt_target: Optional[float] = None
    slo_ok: Optional[bool] = None   # attained? None = carries no deadline

    @property
    def ttft(self) -> Optional[float]:
        return None if self.t_first_token is None else self.t_first_token - self.arrival

    @property
    def e2e(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.arrival

    @property
    def tbt(self) -> Optional[float]:
        if len(self.token_times) < 2:
            return None
        gaps = [b - a for a, b in zip(self.token_times, self.token_times[1:],
                                      strict=False)]
        return sum(gaps) / len(gaps)

    @property
    def tbt_max(self) -> Optional[float]:
        """Worst inter-token gap — what a TBT *deadline* is judged
        against (the mean ``tbt`` hides exactly the stall an SLO exists
        to catch)."""
        if len(self.token_times) < 2:
            return None
        return max(b - a for a, b in zip(self.token_times,
                                         self.token_times[1:], strict=False))


@dataclass
class EngineMetrics:
    requests: Dict[int, RequestMetrics] = field(default_factory=dict)
    kv_usage_trace: List[float] = field(default_factory=list)
    step_kinds: List[str] = field(default_factory=list)
    # scheduler-event trace: dicts {"t", "event": "admit"|"preempt", "rid",
    # ...}; bounded ring (ServeConfig.sched_events_cap), oldest dropped
    sched_events: EventRing = field(default_factory=EventRing)
    # policy-layer counters (core/policies.py): admission_reorders,
    # admission_holds, cheap_preemptions, cost_evictions (ints) and
    # cost_flops_evicted (float)
    policy_counters: Dict[str, float] = field(default_factory=dict)
    # preemptions performed, ever — unlike the sched_events ring this
    # never drops, so step-kind accounting stays lossless at tiny caps
    n_preempt_events: int = 0
    t_start: float = 0.0
    t_end: float = 0.0
    n_steps: int = 0
    # --- prefill accounting (shared-prefix cache) ---
    n_prefill_tokens: int = 0    # prefill tokens actually computed
    n_cached_tokens: int = 0     # prefill tokens skipped via cache hits
                                 # (token-exact: partial-page spans count)
    n_partial_hits: int = 0      # admissions that reused a partial page
                                 # via token-level COW
    # allocator/cache counters snapshot, refreshed by the engine each step:
    # {"n_reclaims", "n_cow", "n_shared_maps", "pages_shared", ...}
    prefix_cache_stats: Dict[str, int] = field(default_factory=dict)
    # --- chunked-prefill planner (core/planner.py, mode="chunked") ---
    n_chunks: int = 0            # prefill chunks dispatched by the planner
    chunk_budget: int = 0        # ServeConfig.chunk_tokens (0 off-mode)
    # packed tokens (prefill chunks + decodes) per mixed round -> rounds
    # dispatched at that packing; occupancy derives from it in summary()
    packed_tokens_hist: Dict[int, int] = field(default_factory=dict)
    # --- KV pool byte accounting (kv_dtype="int8" capacity lever) ---
    kv_pool_bytes: int = 0        # device bytes of the page pool (all pages)
    kv_bytes_per_token: float = 0.0   # page_bytes / page_size (K+V, all layers)
    n_quant_pages: int = 0        # cumulative pages written with int8 KV
    # --- SLO outcomes (finished requests carrying a deadline only) ---
    slo_attained: int = 0
    slo_missed: int = 0

    def req(self, rid: int) -> RequestMetrics:
        if rid not in self.requests:
            self.requests[rid] = RequestMetrics(rid)
        return self.requests[rid]

    def bump(self, counter: str, n: float = 1) -> None:
        """Increment a policy-layer counter (created on first use)."""
        self.policy_counters[counter] = self.policy_counters.get(counter, 0) + n

    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r.t_done is not None]
        total_tokens = sum(r.n_generated for r in done)
        wall = max(self.t_end - self.t_start, 1e-9)
        def agg(vals):
            vals = [v for v in vals if v is not None]
            if not vals:
                return {"mean": None, "p50": None, "max": None}
            return {"mean": sum(vals) / len(vals),
                    "p50": statistics.median(vals),
                    "max": max(vals)}
        return {
            "n_done": len(done),
            "wall_s": wall,
            "throughput_tok_s": total_tokens / wall,
            "ttft": agg([r.ttft for r in done]),
            "tbt": agg([r.tbt for r in done]),
            "e2e": agg([r.e2e for r in done]),
            "n_steps": self.n_steps,
            "n_preemptions": sum(r.n_preempted for r in self.requests.values()),
            "n_preempted_requests": sum(
                1 for r in self.requests.values() if r.n_preempted),
            # lossless engine-side counter; equals n_preemptions unless the
            # event ring dropped (kept separate as the step-kind source)
            "n_preempt_events": self.n_preempt_events,
            "finish_reasons": {
                reason: sum(1 for r in done if r.finish_reason == reason)
                for reason in sorted({r.finish_reason for r in done
                                      if r.finish_reason is not None})},
            "kv_usage_peak": max(self.kv_usage_trace, default=0.0),
            "kv_usage_mean": (sum(self.kv_usage_trace) / len(self.kv_usage_trace))
                             if self.kv_usage_trace else 0.0,
            "prefill_tokens_computed": self.n_prefill_tokens,
            "cached_tokens": self.n_cached_tokens,
            # fraction of all prefill work served from the prefix cache
            "cache_hit_rate": (
                self.n_cached_tokens
                / max(self.n_cached_tokens + self.n_prefill_tokens, 1)),
            "n_partial_hits": self.n_partial_hits,
            "pages_shared_peak": self.prefix_cache_stats.get("pages_shared_peak", 0),
            "n_reclaims": self.prefix_cache_stats.get("n_reclaims", 0),
            "n_cow": self.prefix_cache_stats.get("n_cow", 0),
            "prefix_cache": dict(self.prefix_cache_stats),
            "sched_events_dropped": getattr(self.sched_events, "n_dropped", 0),
            "policy_counters": dict(self.policy_counters),
            "n_chunks": self.n_chunks,
            "kv_pool_bytes": self.kv_pool_bytes,
            "kv_bytes_per_token": self.kv_bytes_per_token,
            "n_quant_pages": self.n_quant_pages,
            # mean packed tokens per mixed round over chunk_tokens; can
            # exceed 1.0 when the decode batch alone outgrows the budget
            "chunk_occupancy": (
                sum(k * v for k, v in self.packed_tokens_hist.items())
                / (self.chunk_budget
                   * max(sum(self.packed_tokens_hist.values()), 1))
                if self.chunk_budget else None),
            "packed_tokens_hist": dict(sorted(self.packed_tokens_hist.items())),
            # SLO outcomes: only requests carrying a deadline count, so
            # attainment is None (not a vacuous 1.0) on deadline-free runs
            "slo_attained": self.slo_attained,
            "slo_missed": self.slo_missed,
            "slo_attainment": (
                self.slo_attained / (self.slo_attained + self.slo_missed)
                if self.slo_attained + self.slo_missed else None),
            "tenants": self._tenant_rollup(done),
        }

    def _tenant_rollup(self, done) -> dict:
        """Per-tenant latency/SLO aggregates over finished requests.
        Omitted entirely (empty dict) when every request rode the
        implicit deadline-free "default" tenant, so single-tenant
        summaries stay byte-stable."""
        by_tenant: Dict[str, list] = {}
        for r in done:
            by_tenant.setdefault(r.tenant, []).append(r)
        if list(by_tenant) == ["default"] and all(
                r.slo_ok is None for r in done):
            return {}
        out = {}
        for tenant in sorted(by_tenant):
            rs = by_tenant[tenant]
            judged = [r for r in rs if r.slo_ok is not None]
            ttfts = sorted(r.ttft for r in rs if r.ttft is not None)
            gaps = sorted(r.tbt_max for r in rs if r.tbt_max is not None)
            def pct(vals, q):
                if not vals:
                    return None
                return vals[min(int(q * (len(vals) - 1) + 0.5),
                                len(vals) - 1)]
            out[tenant] = {
                "n_done": len(rs),
                "slo_attained": sum(1 for r in judged if r.slo_ok),
                "slo_missed": sum(1 for r in judged if not r.slo_ok),
                "slo_attainment": (
                    sum(1 for r in judged if r.slo_ok) / len(judged)
                    if judged else None),
                "ttft_p50": pct(ttfts, 0.50),
                "ttft_p99": pct(ttfts, 0.99),
                "tbt_max_p50": pct(gaps, 0.50),
                "tbt_max_p99": pct(gaps, 0.99),
            }
        return out
