"""Shared-prefix KV cache: a hash-trie of pages with token-level reuse.

The paper's binding constraint is the KV-cache page pool (Figs. 5/14/15);
this module stretches it by turning byte-identical token prefixes —
shared system prompts, few-shot templates, and the ``prompt +
out_tokens`` replay of a preemption resume — into *shared* refcounted
pages instead of recomputed private copies.

Structure
    A trie over pages: each node is keyed by
    ``(parent_node_id, page_token_tuple)`` and records the pool page
    holding the KV for exactly those tokens at those absolute positions.
    Chaining from the root makes position alignment inherent (a page's
    KV embeds its rope positions), and using the parent's node id — not
    a hash of its tokens — makes lookups exact: no collision can map a
    request onto the wrong KV.  Every node also keeps explicit child
    links (``children``), so subtree walks (blocked-reclaimable
    eviction, partial-match scans) never scan the whole table.

Granularity
    Full-page nodes (``n_valid == page_size``) chain; **partial** nodes
    (``n_valid < page_size``) are always leaves: they record the valid
    token count of a page whose tail was never filled (a finished or
    preempted request's last page).  ``match`` walks full pages only;
    ``match_tokens`` additionally scans the divergence point's children
    for the longest token-level overlap, so two prompts that diverge
    *inside* a page still share everything before the divergence — the
    engine copies that page (copy-on-write) and recomputes zero matched
    tokens.

Lifecycle (driven by :class:`~repro.core.kv_cache.PageAllocator`)
    * ``insert`` registers a request's committed full pages after a
      prefill chunk lands, and again at finish/preemption (so a resumed
      victim re-hits its own just-freed pages); terminal inserts may
      register the partial tail page too (``allow_partial``).
    * ``match``/``match_tokens`` return the longest cached prefix for a
      token list; the allocator then ``share``s the full-page hits
      (refcount += 1) and ``cow_partial``s the partial one.
    * When a page's refcount drops to zero it is *not* returned to the
      free list: it parks here as **reclaimable**, still serving future
      hits.  Under pressure the allocator strips reclaimable pages
      (leaf-first, per the eviction policy) *before* the scheduler
      resorts to preempting live requests.

A request's cached span is capped below its full prefill length (at
least one token is always recomputed so the engine has last-token logits
to sample from), and partial hits are materialized as private copies.
Writes therefore never land in shared pages on today's engine paths;
the allocator's copy-on-write (``prepare_write``) is the safety net that
keeps that an invariant rather than an assumption.

Which reclaimable leaf is stripped first is an
:class:`~repro.core.policies.EvictionPolicy` decision (lru / fifo /
cost); the trie only supplies the mechanism — leaf enumeration and the
``page_cost`` recompute-FLOPs proxy the cost model ranks by.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.policies import EVICTION_POLICIES, EvictionPolicy, make_eviction

# legacy alias (pre-policy-layer name); new code should key off
# policies.EVICTION_POLICIES, which adds "cost"
PREFIX_CACHE_POLICIES = tuple(sorted(EVICTION_POLICIES))

_ROOT = 0          # parent id of first-page nodes


def _overlap(a, b) -> int:
    """Length of the common prefix of two token sequences."""
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class _Node:
    __slots__ = ("nid", "key", "page", "parent", "children",
                 "last_used", "reclaimable", "depth", "n_desc")

    def __init__(self, nid: int, key, page: int, parent: Optional["_Node"]):
        self.nid = nid
        self.key = key                  # (parent_nid, page_token_tuple)
        self.page = page
        self.parent = parent
        self.children: Dict[tuple, "_Node"] = {}   # chunk tuple -> node
        self.last_used = 0
        self.reclaimable = False
        self.depth = 0 if parent is None else parent.depth + 1
        self.n_desc = 0                 # cached nodes anywhere below this one

    @property
    def n_valid(self) -> int:
        """Valid tokens in the page; < page_size marks a partial leaf."""
        return len(self.key[1])

    @property
    def n_children(self) -> int:
        return len(self.children)


class PrefixCache:
    """Prefix trie of full-page chains plus partial-leaf tails, with a
    reclaimable (zero-ref) pool."""

    def __init__(self, page_size: int, policy="lru"):
        if isinstance(policy, EvictionPolicy):
            self.default_policy = policy
        else:
            try:
                self.default_policy = make_eviction(policy)
            except ValueError:
                raise ValueError(
                    f"unknown prefix_cache_policy {policy!r}; expected one "
                    f"of {', '.join(sorted(EVICTION_POLICIES))}") from None
        self.page_size = page_size
        self.policy = self.default_policy.name
        self._nodes: Dict[Tuple[int, Tuple[int, ...]], _Node] = {}
        self._roots: Dict[Tuple[int, ...], _Node] = {}  # depth-0 child links
        self._by_page: Dict[int, _Node] = {}
        self._reclaimable: Dict[int, _Node] = {}    # page -> node, ref == 0
        # pages evicted from the trie by the blocked-subtree fallback while
        # still mapped by live requests: they may legitimately stay
        # multi-referenced without being cached (the sanitizer's COW-
        # exclusivity check exempts them); cleared when the owners release
        # the page or a finish re-registers it
        self.orphaned_shared: set = set()
        self._tick = 0
        self._next_nid = _ROOT + 1
        self.n_evicted = 0   # reclaimed/evicted nodes (engine stats)
        self.last_evict_cost = 0.0   # page_cost of the latest pop (trace)

    # ------------------------------------------------------------ lookup ---
    def _chunks(self, tokens: List[int]):
        ps = self.page_size
        for i in range(len(tokens) // ps):
            yield tuple(tokens[i * ps: (i + 1) * ps])

    def _children_of(self, node: Optional[_Node]) -> Dict[tuple, _Node]:
        return self._roots if node is None else node.children

    def _walk(self, tokens: List[int]) -> Tuple[List[int], Optional[_Node]]:
        """Full-page chain walk: hit pages plus the divergence node."""
        pages: List[int] = []
        node: Optional[_Node] = None
        for chunk in self._chunks(tokens):
            nxt = self._children_of(node).get(chunk)
            if nxt is None:
                break
            pages.append(nxt.page)
            node = nxt
        return pages, node

    def match(self, tokens: List[int]) -> List[int]:
        """Pages holding the longest cached full-page prefix of ``tokens``.

        Pure lookup — no refcounts or LRU state change (callers map the
        pages through ``PageAllocator.share`` and then :meth:`touch`).
        Page-granular callers (admission probes in "page" mode,
        ``resume_safe_pages``) use this; it skips ``match_tokens``'s
        divergence-point overlap scan entirely.
        """
        return self._walk(tokens)[0]

    def match_tokens(self, tokens: List[int]
                     ) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Longest cached prefix of ``tokens`` at token granularity.

        Returns ``(pages, partial)``: the full-page chain, plus — when
        the match ends *inside* a page — ``(page, n_matched)`` for the
        cached child sharing the longest strict token prefix with the
        remainder (ties broken most-recently-used, then newest).  The
        partial page cannot be shared in place (its tail belongs to the
        donor); callers copy it via ``PageAllocator.cow_partial``.
        """
        pages, node = self._walk(tokens)
        rest = tokens[len(pages) * self.page_size:]
        best: Optional[Tuple[int, int]] = None
        best_rank = None
        for child in self._children_of(node).values():
            t = _overlap(rest, child.key[1])
            if t <= 0:
                continue
            rank = (t, child.last_used, child.nid)
            if best_rank is None or rank > best_rank:
                best, best_rank = (child.page, t), rank
        return pages, best

    def touch(self, pages: List[int]) -> None:
        """LRU-bump the nodes behind freshly mapped hit pages."""
        self._tick += 1
        for p in pages:
            node = self._by_page.get(p)
            if node is not None:
                node.last_used = self._tick

    # ------------------------------------------------------------ insert ---
    def insert(self, tokens: List[int], pages: List[int],
               allow_partial: bool = False) -> int:
        """Register ``pages`` as holding the KV of ``tokens``.

        By default full pages only (``len(tokens) == len(pages) *
        page_size``; callers trim the partial tail).  With
        ``allow_partial`` a trailing remainder registers the last page
        as a *partial leaf* (``n_valid < page_size``) — only safe at
        terminal points (finish/preemption) where nothing will write
        into that page again.  Existing nodes win — a duplicate prefix
        computed privately by a concurrent request is simply not
        registered (its pages free normally).  Returns the number of
        newly cached pages.
        """
        ps = self.page_size
        n_full, rem = divmod(len(tokens), ps)
        if allow_partial:
            if len(pages) != n_full + (1 if rem else 0):
                raise ValueError(
                    f"insert(allow_partial): {len(tokens)} tokens at "
                    f"page_size {ps} need {n_full + (1 if rem else 0)} "
                    f"pages, got {len(pages)}")
        elif rem != 0 or len(pages) != n_full:
            raise ValueError(
                f"insert: expected whole pages ({len(tokens)} tokens at "
                f"page_size {ps} -> {n_full} full pages, remainder {rem}), "
                f"got {len(pages)} pages; trim the partial tail or pass "
                "allow_partial=True at a terminal point")
        self._tick += 1
        new = 0
        parent: Optional[_Node] = None
        complete = True
        for i, chunk in enumerate(self._chunks(tokens)):
            node = self._children_of(parent).get(chunk)
            if node is None:
                node = self._make_node(chunk, pages[i], parent)
                if node is None:
                    complete = False
                    break       # stale page alias: never double-register
                new += 1
            node.last_used = self._tick
            parent = node
        if rem and complete:
            chunk = tuple(tokens[n_full * ps:])
            node = self._children_of(parent).get(chunk)
            if node is None:
                node = self._make_node(chunk, pages[-1], parent)
                if node is not None:
                    new += 1
            if node is not None:
                node.last_used = self._tick
        return new

    def _make_node(self, chunk: tuple, page: int,
                   parent: Optional[_Node]) -> Optional[_Node]:
        """Create and link one node; None when ``page`` already caches
        other content (stale alias from a racing insert)."""
        if page in self._by_page:
            return None
        parent_id = _ROOT if parent is None else parent.nid
        node = _Node(self._next_nid, (parent_id, chunk), page, parent)
        self._next_nid += 1
        self._nodes[node.key] = node
        self._by_page[page] = node
        self.orphaned_shared.discard(page)   # cached again: contract restored
        self._children_of(parent)[chunk] = node
        if parent is not None:
            anc = parent
            while anc is not None:       # descendant accounting
                anc.n_desc += 1
                anc = anc.parent
        return node

    # --------------------------------------------------- reclaimable pool --
    def is_cached(self, page: int) -> bool:
        return page in self._by_page

    @property
    def n_cached_pages(self) -> int:
        return len(self._by_page)

    @property
    def n_reclaimable(self) -> int:
        return len(self._reclaimable)

    def on_release(self, page: int) -> None:
        """Called by the allocator when a cached page's refcount hits 0:
        park it as reclaimable instead of returning it to the free list."""
        node = self._by_page[page]
        node.reclaimable = True
        self._reclaimable[page] = node

    def on_revive(self, page: int) -> None:
        """A reclaimable page was re-shared (refcount 0 -> 1)."""
        node = self._reclaimable.pop(page)
        node.reclaimable = False

    def page_cost(self, page: int) -> float:
        """Recompute-FLOPs-saved proxy for a cached page (dimensionless,
        model-free): rebuilding the page's ``n_valid`` tokens replays
        the per-token linear work plus attention over everything before
        them, so cost grows with depth — a deep chain page is expensive
        to lose, a shallow long-tail leaf is nearly free.  Pages anchoring
        cached subtrees are weighted by their descendant count (evicting
        them would orphan the whole chain below; relevant to policies
        comparing non-leaf pages — for the leaf-first strip the factor
        is 1).  A partial leaf holds fewer valid tokens than a full page,
        so it is proportionally cheaper to lose.
        """
        node = self._by_page[page]
        nv = node.n_valid
        end = node.depth * self.page_size + nv   # context length at page end
        return (1 + node.n_desc) * nv * (nv + end)

    def pop_reclaimable(self, policy: Optional[EvictionPolicy] = None
                        ) -> Optional[int]:
        """Evict the policy's lowest-ranked zero-ref *leaf* (no cached
        children) and return its page to the caller.  Leaf-first keeps
        every remaining chain intact; since a referenced child implies a
        referenced parent (requests map whole prefix chains), every
        reclaimable page is eventually poppable this way.
        """
        policy = policy or self.default_policy
        best: Optional[_Node] = None
        best_rank = None
        for node in self._reclaimable.values():
            if node.children:
                continue
            r = policy.rank(node, self)
            if best is None or r < best_rank:
                best, best_rank = node, r
        if best is None and self._reclaimable:
            best = self._pop_blocked(policy)
        if best is None:
            return None
        self.last_evict_cost = self.page_cost(best.page)
        self._evict(best)
        return best.page

    def _pop_blocked(self, policy: EvictionPolicy) -> Optional[_Node]:
        """Rare fallback: every reclaimable page sits above *referenced*
        descendants, so no leaf is strippable.  (Engine paths never get
        here — they only write at the sequence tail — but an interior
        ``prepare_write`` COW releases a mid-chain page while its chain
        stays mapped.)  ``n_free`` counts every reclaimable page, so the
        capacity promise must be kept: take the best-ranked reclaimable
        with no reclaimable below it and evict its whole (all-referenced)
        subtree from the trie — descendant pages stay owned by their
        requests, they just stop being cached, and return to the free
        list when their owners release them."""
        blocked = set()
        for node in self._reclaimable.values():
            anc = node.parent
            while anc is not None:
                if anc.reclaimable:
                    blocked.add(anc.nid)
                anc = anc.parent
        best: Optional[_Node] = None
        best_rank = None
        for node in self._reclaimable.values():
            if node.nid in blocked:
                continue
            r = policy.rank(node, self)
            if best is None or r < best_rank:
                best, best_rank = node, r
        if best is None:        # unreachable: the deepest reclaimable in
            return None         # any chain is never blocked
        doomed = []             # subtree via explicit child links — no
        stack = list(best.children.values())        # O(nodes) table scan
        while stack:
            node = stack.pop()
            doomed.append(node)
            stack.extend(node.children.values())
        for node in sorted(doomed, key=lambda n: -n.depth):
            self._evict(node)   # leaf-upward keeps child counts consistent
            self.orphaned_shared.add(node.page)  # still owned, no longer cached
        return best             # now a leaf; caller evicts and returns it

    def _evict(self, node: _Node) -> None:
        del self._nodes[node.key]
        del self._by_page[node.page]
        self._reclaimable.pop(node.page, None)
        del self._children_of(node.parent)[node.key[1]]
        if node.parent is not None:
            anc = node.parent
            while anc is not None:
                anc.n_desc -= 1
                anc = anc.parent
        self.n_evicted += 1
