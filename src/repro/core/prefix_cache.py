"""Shared-prefix KV cache: a hash-trie of full pages.

The paper's binding constraint is the KV-cache page pool (Figs. 5/14/15);
this module stretches it by turning byte-identical token prefixes —
shared system prompts, few-shot templates, and the ``prompt +
out_tokens`` replay of a preemption resume — into *shared* refcounted
pages instead of recomputed private copies.

Structure
    A trie over *full* pages: each node is keyed by
    ``(parent_node_id, page_token_tuple)`` and records the pool page
    holding the KV for exactly those ``page_size`` tokens at those
    absolute positions.  Chaining from the root makes position alignment
    inherent (a page's KV embeds its rope positions), and using the
    parent's node id — not a hash of its tokens — makes lookups exact:
    no collision can map a request onto the wrong KV.

Lifecycle (driven by :class:`~repro.core.kv_cache.PageAllocator`)
    * ``insert`` registers a request's committed full pages after a
      prefill chunk lands, and again at finish/preemption (so a resumed
      victim re-hits its own just-freed pages).
    * ``match`` returns the longest cached full-page prefix for a token
      list; the allocator then ``share``s those pages (refcount += 1).
    * When a page's refcount drops to zero it is *not* returned to the
      free list: it parks here as **reclaimable**, still serving future
      hits.  Under pressure the allocator strips reclaimable pages
      (leaf-first, LRU or FIFO per ``prefix_cache_policy``) *before* the
      scheduler resorts to preempting live requests.

Only full pages are cached, and a request's cached span is capped below
its full prefill length (at least one token is always recomputed so the
engine has last-token logits to sample from).  Writes therefore never
land in shared pages on today's engine paths; the allocator's
copy-on-write (``prepare_write``) is the safety net that keeps that an
invariant rather than an assumption.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

PREFIX_CACHE_POLICIES = ("lru", "fifo")

_ROOT = 0          # parent id of first-page nodes


class _Node:
    __slots__ = ("nid", "key", "page", "parent", "n_children", "last_used",
                 "reclaimable")

    def __init__(self, nid: int, key, page: int, parent: Optional["_Node"]):
        self.nid = nid
        self.key = key                  # (parent_nid, page_token_tuple)
        self.page = page
        self.parent = parent
        self.n_children = 0
        self.last_used = 0
        self.reclaimable = False


class PrefixCache:
    """Page-granular prefix trie with a reclaimable (zero-ref) pool."""

    def __init__(self, page_size: int, policy: str = "lru"):
        if policy not in PREFIX_CACHE_POLICIES:
            raise ValueError(
                f"unknown prefix_cache_policy {policy!r}; expected one of "
                f"{', '.join(PREFIX_CACHE_POLICIES)}")
        self.page_size = page_size
        self.policy = policy
        self._nodes: Dict[Tuple[int, Tuple[int, ...]], _Node] = {}
        self._by_page: Dict[int, _Node] = {}
        self._reclaimable: Dict[int, _Node] = {}    # page -> node, ref == 0
        self._tick = 0
        self._next_nid = _ROOT + 1
        self.n_evicted = 0   # reclaimed/evicted nodes (engine stats)

    # ------------------------------------------------------------ lookup ---
    def _chunks(self, tokens: List[int]):
        ps = self.page_size
        for i in range(len(tokens) // ps):
            yield tuple(tokens[i * ps: (i + 1) * ps])

    def match(self, tokens: List[int]) -> List[int]:
        """Pages holding the longest cached full-page prefix of ``tokens``.

        Pure lookup — no refcounts or LRU state change (callers map the
        pages through ``PageAllocator.share`` and then :meth:`touch`).
        """
        pages: List[int] = []
        parent = _ROOT
        for chunk in self._chunks(tokens):
            node = self._nodes.get((parent, chunk))
            if node is None:
                break
            pages.append(node.page)
            parent = node.nid
        return pages

    def touch(self, pages: List[int]) -> None:
        """LRU-bump the nodes behind freshly mapped hit pages."""
        self._tick += 1
        for p in pages:
            node = self._by_page.get(p)
            if node is not None:
                node.last_used = self._tick

    # ------------------------------------------------------------ insert ---
    def insert(self, tokens: List[int], pages: List[int]) -> int:
        """Register ``pages`` as holding the KV of ``tokens`` (full pages
        only: ``len(tokens) == len(pages) * page_size``; callers trim the
        partial tail).  Existing nodes win — a duplicate prefix computed
        privately by a concurrent request is simply not registered (its
        pages free normally).  Returns the number of newly cached pages.
        """
        assert len(tokens) == len(pages) * self.page_size, \
            (len(tokens), len(pages), self.page_size)
        self._tick += 1
        new = 0
        parent: Optional[_Node] = None
        parent_id = _ROOT
        for i, chunk in enumerate(self._chunks(tokens)):
            key = (parent_id, chunk)
            node = self._nodes.get(key)
            if node is None:
                page = pages[i]
                if page in self._by_page:
                    # page already caches other content (stale alias from a
                    # racing insert) — never double-register a page
                    break
                node = _Node(self._next_nid, key, page, parent)
                self._next_nid += 1
                self._nodes[key] = node
                self._by_page[page] = node
                if parent is not None:
                    parent.n_children += 1
                new += 1
            node.last_used = self._tick
            parent, parent_id = node, node.nid
        return new

    # --------------------------------------------------- reclaimable pool --
    def is_cached(self, page: int) -> bool:
        return page in self._by_page

    @property
    def n_cached_pages(self) -> int:
        return len(self._by_page)

    @property
    def n_reclaimable(self) -> int:
        return len(self._reclaimable)

    def on_release(self, page: int) -> None:
        """Called by the allocator when a cached page's refcount hits 0:
        park it as reclaimable instead of returning it to the free list."""
        node = self._by_page[page]
        node.reclaimable = True
        self._reclaimable[page] = node

    def on_revive(self, page: int) -> None:
        """A reclaimable page was re-shared (refcount 0 -> 1)."""
        node = self._reclaimable.pop(page)
        node.reclaimable = False

    def pop_reclaimable(self) -> Optional[int]:
        """Evict the best zero-ref *leaf* (no cached children) and return
        its page to the caller.  Leaf-first keeps every remaining chain
        intact; since a referenced child implies a referenced parent
        (requests map whole prefix chains), every reclaimable page is
        eventually poppable this way.
        """
        def rank(node: _Node) -> int:
            return node.last_used if self.policy == "lru" else node.nid

        best: Optional[_Node] = None
        for node in self._reclaimable.values():
            if node.n_children:
                continue
            if best is None or rank(node) < rank(best):
                best = node
        if best is None:
            return None
        self._evict(best)
        return best.page

    def _evict(self, node: _Node) -> None:
        del self._nodes[node.key]
        del self._by_page[node.page]
        self._reclaimable.pop(node.page, None)
        if node.parent is not None:
            node.parent.n_children -= 1
        self.n_evicted += 1
