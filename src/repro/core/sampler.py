"""Per-request token sampling: greedy / temperature / top-k / top-p.

``SamplingParams`` travels with each :class:`~repro.core.engine.Request`
(vLLM-style); the engine lowers a batch of heterogeneous requests into
per-row parameter *arrays* and dispatches ONE jitted kernel
(:func:`sample_tokens`) — no static-argument retraces per knob
combination, so mixed batches (greedy next to temperature-0.8 next to
top-k) share a single compile per shape.

Determinism: row ``i``'s PRNG key is derived from
``(seed, rid, position)`` — the request's own seed, its id, and the
index of the token being sampled — never from engine state.  Sampled
outputs are therefore independent of batch composition, engine mode,
and preemption/resume history (the properties
``tests/test_api.py`` pins down).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters (attached to ``Request.sampling``).

    ``temperature == 0`` means greedy (argmax); ``top_k == 0`` and
    ``top_p == 1.0`` disable their filters.  ``eos_id`` /
    ``stop_token_ids`` end generation early with
    ``finish_reason="stop"``; ``max_new_tokens`` ends it with
    ``finish_reason="length"``.
    """
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: Optional[int] = None
    stop_token_ids: Tuple[int, ...] = ()
    seed: int = 0

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.eos_id is not None and (
                not isinstance(self.eos_id, int)
                or isinstance(self.eos_id, bool) or self.eos_id < 0):
            raise ValueError(
                f"eos_id must be a non-negative int or None, got "
                f"{self.eos_id!r}")
        if not isinstance(self.stop_token_ids, tuple) or any(
                not isinstance(t, int) or isinstance(t, bool) or t < 0
                for t in self.stop_token_ids):
            raise ValueError(
                f"stop_token_ids must be a tuple of non-negative ints, got "
                f"{self.stop_token_ids!r}")
        if not isinstance(self.seed, int) or isinstance(self.seed, bool) \
                or self.seed < 0:
            raise ValueError(
                f"seed must be a non-negative int (PRNGKey seed), got "
                f"{self.seed!r}")

    @property
    def stop_set(self) -> frozenset:
        s = frozenset(self.stop_token_ids)
        return s if self.eos_id is None else s | {self.eos_id}


@jax.jit
def greedy_tokens(logits):
    """Fast path for all-greedy batches (the serving hot path): plain
    argmax, skipping the sort/softmax/categorical machinery entirely.
    Bit-identical to sample_tokens rows with temperature <= 0."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _row_key(seed, rid, pos):
    """Independent stream per (request seed, request id, token index)."""
    return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), rid), pos)


@jax.jit
def sample_tokens(logits, temperature, top_k, top_p, seed, rid, pos):
    """logits [B, V] + per-row parameter arrays [B] -> tokens [B] int32.

    Every row is processed with its own knobs in one program: rows with
    ``temperature <= 0`` take the exact argmax (bit-identical to a pure
    greedy engine); the rest are temperature-scaled, top-k- then
    top-p-masked, and sampled from their ``(seed, rid, pos)`` stream.
    """
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    lg = logits / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: mask strictly below each row's k-th largest logit (k=0 -> off)
    sorted_lg = jnp.sort(lg, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(sorted_lg, jnp.clip(top_k - 1, 0, V - 1)[:, None],
                              axis=-1)
    lg = jnp.where((top_k[:, None] > 0) & (lg < kth), -1e30, lg)
    # top-p (nucleus) on the top-k-masked logits (p=1 -> off)
    sorted_lg = jnp.sort(lg, axis=-1)[:, ::-1]
    cum = jnp.cumsum(jax.nn.softmax(sorted_lg, axis=-1), axis=-1)
    cutoff_idx = jnp.sum(cum < top_p[:, None], axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_lg, jnp.clip(cutoff_idx, 0, V - 1),
                                 axis=-1)
    lg = jnp.where((top_p[:, None] < 1.0) & (lg < cutoff), -1e30, lg)

    keys = jax.vmap(_row_key)(seed, rid, pos)
    sampled = jax.vmap(lambda k, row: jax.random.categorical(k, row))(keys, lg)
    return jnp.where(temperature <= 0.0, greedy, sampled.astype(jnp.int32))
