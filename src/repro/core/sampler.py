"""Token sampling: greedy / temperature / top-k / top-p, pure JAX."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("temperature", "top_k", "top_p"))
def sample(logits, key, temperature=0.0, top_k=0, top_p=1.0):
    """logits [B, V] -> tokens [B] int32. Sampling knobs are static."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if temperature == 0.0:
        return greedy
    lg = logits / max(temperature, 1e-6)
    if top_k:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -1e30, lg)
    if top_p < 1.0:
        sorted_lg = jnp.sort(lg, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_lg, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_lg, cutoff_idx, axis=-1)
        lg = jnp.where(lg < cutoff, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
