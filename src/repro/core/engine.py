"""The Splitwiser serving engine.

Modes (each maps to one of the paper's experimental arms; the benchmark
suites that exercise them are catalogued in EXPERIMENTS.md):

  sequential      — vLLM-style continuous batching: each engine step is
                    EITHER a full-prompt prefill batch OR a decode batch
                    (the paper's baseline, Fig. 6/8/10 "SP"/"Sequential").
  splitwiser      — phase splitting with time-sliced interleave: prompt
                    chunks and decode batches run as *separate* programs on
                    alternating steps (the paper's PyTorch-multiprocessing-
                    without-MPS arm; on a GPU these context-switch, Fig. 10
                    "MPx2").
  splitwiser_mps  — the paper's headline: both phases co-resident. On TPU
                    this is the FUSED mixed step: decode tokens + prefill
                    chunks share every GEMM in one XLA program (Fig. 9/10
                    "MPSx2"; also the paper's own stated next step, mixed
                    batching, §III-C1).
  chunked         — SARATHI-style chunked prefill with piggybacked
                    decodes: a ChunkPlanner (core/planner.py) carves
                    in-flight prefills into fixed-token-budget chunks
                    (ServeConfig.chunk_tokens), decode tokens claim their
                    budget share first, and the whole round is ONE mixed
                    dispatch — flat compute intensity, tail TBT bounded
                    by the chunk budget even under 2k-token prompts.
                    Admission budgets per-chunk pages (scheduler), so
                    new requests interleave with in-flight prefills.

("mp2" — two replicas with split resources — is built from two
"sequential" engines by benchmarks/splitwiser_vllm.py, not a mode here.)

The engine is host-driven with statically-shaped jitted steps (the TPU
analogue of "instantiate the process once and feed it through queues",
paper §V): P prefill streams (the paper's #processes knob) x C-token
chunks + B decode slots.

Request/response surface (vLLM-shaped):

  * each ``Request`` carries its own ``SamplingParams`` (greedy requests
    batch with sampled ones — one jitted sampler vectorized over per-row
    parameter arrays);
  * ``step()`` returns the step's ``TokenEvent`` list, ``stream()``
    yields events as they happen, ``poll()`` drains finished
    ``RequestOutput``s;
  * ``submit()`` is legal mid-run, and ``run(reqs, open_loop=True)``
    feeds requests in at their ``arrival`` offsets against a virtual
    clock that fast-forwards idle gaps (timed/open-loop workloads
    without wall-clock sleeps).

With ``ServeConfig.enable_prefix_cache`` the engine consults a
shared-prefix KV cache (``core/prefix_cache.py``) at every admission:
hit pages are refcount-mapped into the request's block table and prefill
starts at the first uncached token — ``sequential`` computes only the
suffix through the paged mixed kernel, the splitwiser modes fast-forward
their streams past cached chunks, and preempted victims resume by
remapping their own just-freed pages.  At token granularity
(``prefix_cache_granularity="token"``, the default) a prompt that
diverges *inside* a page still reuses the matched span: the partially
matched page is copy-on-write copied into the request's table and
prefill starts mid-page, recomputing zero matched tokens.

Scheduling decisions — admission order, reclaimable-page eviction,
preemption victim choice — are pluggable policies (``core/policies.py``,
selected by ``ServeConfig.admission_policy`` / ``eviction_policy`` /
``preempt_policy``).  The engine supplies the policy inputs: an
*in-flight prefix registry* (``register_inflight`` — which prefills are
about to insert cache pages, so ``cache_aware`` admission can hold an
identical waiting prompt one round instead of double-missing), the
``cache_probe`` trie walk, and ``resume_safe_pages`` (how much of a
victim's committed KV would survive its own eviction).  Policies change
*when* work happens, never *what* is computed: token streams are
bit-identical across every policy combination.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.dispatch import DispatchSentinel
from repro.analysis.invariants import KVSanitizer
from repro.configs.base import ServeConfig
from repro.kernels.kv_int8 import (fake_quant_kv, init_pages_int8,
                                   int8_chunk_attn, int8_decode_attn,
                                   kv_page_bytes, quant_kv)
from repro.core.kv_cache import (KVQuantSidecar, PageAllocator,
                                 pool_pages_from_bytes)
from repro.core.metrics import EngineMetrics, EventRing
from repro.core.outputs import RequestOutput, TokenEvent
from repro.core.planner import ChunkPlan, ChunkPlanner
from repro.core.prefix_cache import PrefixCache
from repro.core.sampler import SamplingParams, greedy_tokens, sample_tokens
from repro.core.scheduler import Scheduler
from repro.core.slo import EffectiveSLO, SLOParams, resolve_slo, slo_outcome
from repro.models import transformer as T


@dataclass
class Request:
    """One generation request.

    ``arrival=None`` (the default) means "stamp me at submit time"; an
    explicit value — including ``0.0`` — is preserved, and in open-loop
    runs is interpreted as an offset (seconds) from the run's start.
    """
    rid: int
    prompt: List[int]
    # default_factory: a shared default instance would alias one params
    # object across every request constructed without explicit sampling
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival: Optional[float] = None
    out_tokens: List[int] = field(default_factory=list)
    # per-request SLO: TTFT/TBT deadlines + tenant id (core/slo.py);
    # unset targets inherit the tenant's ServeConfig tier.  default_factory
    # for the same aliasing reason as ``sampling``
    slo: SLOParams = field(default_factory=SLOParams)

    @property
    def max_new_tokens(self) -> int:
        return self.sampling.max_new_tokens

    @property
    def prefill_tokens(self) -> List[int]:
        """Tokens to (re-)prefill: the prompt plus anything generated
        before a preemption, so a resumed request picks up exactly where
        it stopped."""
        return self.prompt + self.out_tokens


@dataclass
class _Stream:            # an in-progress chunked prefill (one "process")
    req: Request
    tokens: List[int]     # req.prefill_tokens captured at admission
    pos: int = 0          # tokens prefilled so far


@dataclass
class _Slot:              # an active decode sequence
    req: Request
    seq_len: int
    next_token: int


class _Clock:
    """Monotonic engine clock: real time plus a fast-forward offset.

    Open-loop runs jump the offset over idle gaps (nothing to serve until
    the next arrival) so timed workloads replay at full speed while every
    timestamp — metrics, events, scheduler trace — stays on one timeline.
    """

    def __init__(self, base_time_fn):
        self._base = base_time_fn
        self._offset = 0.0

    def __call__(self) -> float:
        return self._base() + self._offset

    def advance_to(self, t: float) -> None:
        self._offset += max(0.0, t - self())


class Engine:
    """Paged-KV serving engine for the transformer family (dense/moe/vlm)."""

    def __init__(self, model, params, serve: ServeConfig, *,
                 time_fn=time.perf_counter):
        if model.cache_kind != "paged":
            raise TypeError(
                f"Engine supports paged-cache archs; got {model.cache_kind} "
                "(state/encdec/hybrid serve paths are exercised via "
                "launch/dryrun)")
        self.model = model
        self.cfg = model.cfg
        self.serve = serve
        self.params = params
        self.now = _Clock(time_fn)
        self.metrics = EngineMetrics(
            sched_events=EventRing(serve.sched_events_cap))
        self.prefix_cache = (
            PrefixCache(serve.page_size,
                        policy=serve.resolved_eviction_policy)
            if serve.enable_prefix_cache else None)
        # byte-denominated page pool: the budget defaults to n_pages
        # fp-width pages, so flipping kv_dtype="int8" alone holds the pool
        # BYTES constant and grows the page COUNT (codes + f32 scale
        # sidecar are narrower than fp tokens) — the capacity lever.
        dtype = jax.tree.leaves(params)[0].dtype
        fp_page_bytes = kv_page_bytes(self.cfg, serve.page_size, dtype)
        page_bytes = kv_page_bytes(self.cfg, serve.page_size, dtype,
                                   kv_dtype=serve.kv_dtype)
        budget = (serve.kv_pool_bytes if serve.kv_pool_bytes is not None
                  else serve.n_pages * fp_page_bytes)
        n_pages = pool_pages_from_bytes(budget, page_bytes)
        # int8 sidecar mirror: page id -> scale-entry count (host-side
        # shadow of which pool pages hold quantized contents)
        self.kv_quant = (KVQuantSidecar()
                         if serve.kv_dtype == "int8" else None)
        self.alloc = PageAllocator(n_pages, serve.page_size,
                                   cache=self.prefix_cache,
                                   event_cb=self._alloc_event,
                                   page_bytes=page_bytes)
        self.metrics.kv_pool_bytes = n_pages * page_bytes
        self.metrics.kv_bytes_per_token = page_bytes / serve.page_size
        self._pages_shared_peak = 0
        # rid -> prefill tokens of admitted-but-not-yet-committed prefills;
        # cache_aware admission holds identical waiting prompts one round
        # so they hit the pages these are about to insert
        self._inflight: dict = {}
        # multi-tenant SLO tiers (ServeConfig.tenants) + per-rid resolved
        # EffectiveSLO cache: the deadline policies, chunk planner and
        # quota checks all read effective_slo() on hot paths, and the
        # resolution is pure per request
        self.tiers = {t.name: t for t in serve.tenants}
        self._slo_cache: dict = {}
        self.streams: List[Optional[_Stream]] = [None] * serve.n_streams
        self.slots: List[Optional[_Slot]] = [None] * serve.max_batch
        self.block_tables = np.zeros((serve.max_batch, serve.max_pages_per_seq),
                                     np.int32)
        self.stream_tables = np.zeros((serve.n_streams, serve.max_pages_per_seq),
                                      np.int32)
        if serve.kv_dtype == "int8":
            self.k_pages, self.v_pages = init_pages_int8(
                self.cfg, n_pages, serve.page_size)
        else:
            self.k_pages, self.v_pages = T.init_pages(
                self.cfg, n_pages, serve.page_size, dtype=dtype)
        self._step_parity = 0
        self._events: List[TokenEvent] = []
        self._outputs: List[RequestOutput] = []
        # chunked mode: the phase planner owns the per-round packing
        # decision; every other mode dispatches phases monolithically
        self.planner = (ChunkPlanner(serve.chunk_tokens, serve.n_streams)
                        if serve.mode == "chunked" else None)
        if self.planner is not None:
            self.metrics.chunk_budget = serve.chunk_tokens
        self.sched = Scheduler(self)
        # read-only runtime invariant checker (analysis/invariants.py);
        # None at the default "off" level so hot paths pay one None test
        self.sanitizer = (KVSanitizer(self)
                          if serve.sanitize_level != "off" else None)
        # jit-dispatch sentinel (analysis/dispatch.py): counts compiles per
        # step callable and raises on recompile storms / post-warmup budget
        self.dispatch = (DispatchSentinel()
                         if serve.dispatch_sentinel else None)
        self._build_jits()

    @property
    def waiting(self) -> "deque[Request]":
        return self.sched.waiting

    # ------------------------------------------------------------- jits ----
    def _build_jits(self):
        cfg = self.cfg

        int8 = self.serve.kv_dtype == "int8"

        # full prefill returning per-row last-token logits; in int8 mode
        # attention reads fake-quantized K/V so the one-shot path is
        # numerically identical to the chunked paths, which re-read
        # earlier chunks from quantized pages (cross-mode bit-identity)
        def prefill_full(params, tokens, lens):
            x = T.embed(params, cfg, tokens)
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            hidden, _, kv = T.forward_hidden(
                params, cfg, x, positions, collect_kv=True,
                kv_fake_quant=fake_quant_kv if int8 else None)
            hl = hidden[jnp.arange(B), jnp.clip(lens - 1, 0, S - 1)]
            return T.unembed(params, cfg, hl), kv
        # int8 routing: prefill-commit QUANTIZES (fp paged KV -> codes +
        # per-(token,head) scale written side by side), decode/mixed
        # DEQUANTIZE in-kernel via the pluggable attn fns; fp path is the
        # seed behaviour, byte for byte.
        attn_decode = int8_decode_attn if int8 else None
        attn_mixed = ({"decode": int8_decode_attn, "chunk": int8_chunk_attn}
                      if int8 else None)

        def commit(kpg, vpg, k_new, v_new, dest):
            # k_new [L, M, ps, KV_p, hd]; dest [M] page ids (trash for pads)
            if int8:
                kq, vq = quant_kv(k_new, v_new)
                return ({"q": kpg["q"].at[:, dest].set(kq["q"]),
                         "s": kpg["s"].at[:, dest].set(kq["s"])},
                        {"q": vpg["q"].at[:, dest].set(vq["q"]),
                         "s": vpg["s"].at[:, dest].set(vq["s"])})
            return kpg.at[:, dest].set(k_new), vpg.at[:, dest].set(v_new)

        def decode_fn(params, tokens, kpg, vpg, bt, lens, active):
            return T.decode(params, cfg, tokens, kpg, vpg, bt, lens,
                            active=active, attn_fn=attn_decode)

        def mixed_fn(params, mb, kpg, vpg):
            return T.mixed(params, cfg, mb, kpg, vpg, attn_fn=attn_mixed)

        # prefill/commit batches legitimately vary with workload shape, so
        # the sentinel only counts them; decode/mixed/samplers are the
        # steady-state step loop where any compile density is a bug.
        self._prefill = self._sentineled("prefill", jax.jit(prefill_full),
                                         storm_guard=False)
        self._commit = self._sentineled(
            "commit", jax.jit(commit, donate_argnums=(0, 1)),
            storm_guard=False)
        self._decode = self._sentineled(
            "decode", jax.jit(decode_fn, donate_argnums=(2, 3)))
        self._mixed = self._sentineled(
            "mixed", jax.jit(mixed_fn, donate_argnums=(2, 3)))
        self._greedy = self._sentineled("sample_greedy", greedy_tokens)
        self._sample = self._sentineled("sample", sample_tokens)

    def _sentineled(self, name, fn, storm_guard: bool = True):
        if self.dispatch is None:
            return fn
        return self.dispatch.wrap(name, fn, storm_guard=storm_guard)

    # ------------------------------------------------------------ public ---
    def submit(self, req: Request):
        """Enqueue a request; legal at any point, including mid-run."""
        if req.rid in self.metrics.requests:
            raise ValueError(
                f"duplicate request id {req.rid}: metrics/page ownership are "
                "keyed by rid, so each submitted request needs a fresh one")
        if req.arrival is None:
            req.arrival = self.now()
        self.sched.submit(req)
        m = self.metrics.req(req.rid)
        m.arrival = req.arrival
        m.n_prompt = len(req.prompt)
        eff = self.effective_slo(req)   # clock-free (pure resolution)
        m.tenant = eff.tenant
        m.ttft_target = eff.ttft_target
        m.tbt_target = eff.tbt_target

    def run(self, requests: List[Request], max_steps: int = 100_000, *,
            open_loop: bool = False) -> EngineMetrics:
        """Drive the engine until every request (plus anything already
        submitted) finishes.  ``open_loop=True`` feeds ``requests`` in at
        their ``arrival`` offsets instead of all at once."""
        self.metrics.t_start = self.now()
        for _ in self.stream(requests, max_steps=max_steps, open_loop=open_loop):
            pass
        self.metrics.t_end = self.now()
        return self.metrics

    def stream(self, requests: List[Request] = (), *, open_loop: bool = False,
               max_steps: int = 100_000) -> Iterator[TokenEvent]:
        """Yield ``TokenEvent``s as the engine generates them.

        Closed loop (default): submit everything up front.  Open loop:
        treat each request's ``arrival`` as an offset from now on the
        virtual clock, submitting it when the clock reaches it and
        fast-forwarding over idle gaps.
        """
        t0 = self.now()      # bound for both loops: the arrival-feed
        if open_loop:        # condition below reads it unconditionally
            pending = deque(sorted(requests,
                                   key=lambda r: (r.arrival or 0.0, r.rid)))
        else:
            pending = deque()
            for r in requests:
                self.submit(r)
        steps = 0
        while (pending or not self.idle()) and steps < max_steps:
            while pending and t0 + (pending[0].arrival or 0.0) <= self.now():
                r = pending.popleft()
                r.arrival = t0 + (r.arrival or 0.0)
                self.submit(r)
            if pending and self.idle():
                self.now.advance_to(t0 + (pending[0].arrival or 0.0))
                continue
            yield from self.step()
            steps += 1

    def poll(self) -> List[RequestOutput]:
        """Drain the ``RequestOutput`` of every request finished since the
        last poll (in finish order)."""
        out, self._outputs = self._outputs, []
        return out

    def idle(self) -> bool:
        return (not self.waiting and all(s is None for s in self.streams)
                and all(s is None for s in self.slots))

    # ------------------------------------------------------ prefix cache ---
    def _alloc_event(self, event: str, **detail):
        """Allocator trace hook (reclaim / cow) into the scheduler trace."""
        if self.kv_quant is not None and event in ("reclaim", "page_free"):
            # the page's quantized contents are dead: retire its scale entry
            self.kv_quant.drop(detail["page"])
        if event == "page_free":
            return      # sidecar-only bookkeeping, not a scheduler decision
        if event == "reclaim" and self.prefix_cache is not None and \
                self.prefix_cache.policy == "cost":
            self.metrics.bump("cost_evictions")
            self.metrics.bump("cost_flops_evicted", detail.get("cost", 0.0))
        self.metrics.sched_events.append(
            {"t": self.now(), "event": event, **detail})

    # ------------------------------------------------------ policy inputs ---
    def register_inflight(self, req: Request) -> None:
        """Record an admitted prefill as in flight: its full pages will
        land in the prefix cache as chunks commit.  The registry is what
        lets ``cache_aware`` admission hold an identical waiting prompt
        one round (hit) instead of admitting it alongside its twin
        (double miss).  Entries are removed at prefill completion
        (``_emit_first_token``) and at preemption, so a held request is
        never stranded behind a prefill that stopped."""
        if self.prefix_cache is not None:
            self._inflight[req.rid] = req.prefill_tokens

    def unregister_inflight(self, rid: int) -> None:
        self._inflight.pop(rid, None)

    def effective_slo(self, req: Request) -> EffectiveSLO:
        """``req``'s tier-resolved SLO (core/slo.py), cached per rid —
        the single answer the deadline policies, tenant quotas, chunk
        planner and SLO metrics all read.  Pure: no clock access."""
        eff = self._slo_cache.get(req.rid)
        if eff is None:
            eff = self._slo_cache[req.rid] = resolve_slo(req.slo, self.tiers)
        return eff

    def inflight_hit_pages(self, req: Request) -> int:
        """Best full-page prefix coverage of ``req``'s prefill that some
        in-flight prefill will have inserted once it commits (capped one
        token below the prefill length, like ``_cache_match``)."""
        if self.prefix_cache is None or not self._inflight:
            return 0
        toks = req.prefill_tokens
        ps = self.serve.page_size
        cap = (len(toks) - 1) // ps
        best = 0
        for other in self._inflight.values():
            lim = min(cap, len(other) // ps)
            n = 0
            while (n < lim and
                   toks[n * ps:(n + 1) * ps] == other[n * ps:(n + 1) * ps]):
                n += 1
            best = max(best, n)
        return best

    def resume_safe_pages(self, req: Request, committed: int) -> int:
        """Full pages of ``req``'s first ``committed`` tokens that would
        survive its own eviction: cached trie pages referenced by at
        least one OTHER live request.  Those keep serving hits after the
        victim's refcounts drop, so its resume remaps them instead of
        recomputing — the ``cache_aware`` PreemptPolicy's score.

        No ``_cache_match``-style cap is needed here: a victim's resume
        prefill is always at least one token longer than ``committed``
        (a slot's last generated token is in ``out_tokens`` but not in
        ``seq_len``; a stream's ``pos`` is short of its tokens), so the
        resume-side cap never truncates these committed full pages."""
        if self.prefix_cache is None:
            return 0
        toks = (req.prompt + req.out_tokens)[:committed]
        pages = self.prefix_cache.match(toks)
        owned = set(self.alloc.owned(req.rid))
        return sum(1 for p in pages
                   if self.alloc.ref_count(p) >= (2 if p in owned else 1))

    def _cache_match(self, tokens: List[int]):
        """(n_cached_tokens, hit_pages, partial) for ``tokens``.

        ``hit_pages`` are full-page hits shared in place; ``partial`` is
        ``(donor_page, n_matched)`` when — at token granularity — the
        match continues *inside* a cached page, reused via a COW copy
        (``PageAllocator.cow_partial``) so no matched token is ever
        recomputed.  The total span is capped at least one token below
        the prefill length: the engine always recomputes the final token
        (it needs its logits to sample from), so cached spans never reach
        a position the engine will write — shared pages stay read-only on
        every engine path (``PageAllocator.prepare_write`` guards the
        rest).
        """
        if self.prefix_cache is None:
            return 0, [], None
        ps = self.serve.page_size
        token_level = self.serve.prefix_cache_granularity == "token"
        if token_level:
            pages, partial = self.prefix_cache.match_tokens(tokens)
        else:
            pages, partial = self.prefix_cache.match(tokens), None
        cap_tokens = len(tokens) - 1
        cap_pages = cap_tokens // ps
        if len(pages) > cap_pages:
            # the whole prompt is cached: the capped-off page still serves
            # the tokens up to the cap as a partial donor
            t = cap_tokens - cap_pages * ps
            partial = ((pages[cap_pages], t)
                       if token_level and t > 0 else None)
            pages = pages[:cap_pages]
        elif partial is not None:
            t = min(partial[1], cap_tokens - len(pages) * ps)
            partial = (partial[0], t) if t > 0 else None
        n = len(pages) * ps + (partial[1] if partial else 0)
        return n, pages, partial

    def cache_probe(self, req: Request):
        """One trie walk answering the admission questions:
        ``(n_hit, n_free, cow_extra)`` — pages of ``req``'s next prefill
        the cache would serve (remap instead of recompute), the subset of
        those already referenced by a live request, which are
        *budget-free*, and a transient extra page to reserve when a
        token-level partial hit must revive an unreferenced donor while
        its COW copy is prepared (the donor parks reclaimable again once
        the copy exists, but both hold capacity for a moment).  The
        scheduler charges everything else — misses AND reclaimable hits,
        since reviving a parked page consumes free capacity just like a
        fresh allocation (it only saves the recompute)."""
        _, pages, partial = self._cache_match(req.prefill_tokens)
        cow_extra = int(partial is not None
                        and not self.alloc.is_referenced(partial[0]))
        return (len(pages),
                sum(1 for p in pages if self.alloc.is_referenced(p)),
                cow_extra)

    def _map_cached(self, req: Request) -> int:
        """Admission-time cache consult: map full-page hits into the
        request's refcounted ownership, materialize a token-level partial
        hit as a private COW copy of its donor page, and return the exact
        cached token count.  Prefill then starts at the first uncached
        token — possibly mid-page."""
        cache = self.prefix_cache
        if cache is None:
            return 0
        n, pages, partial = self._cache_match(req.prefill_tokens)
        if pages:
            self.alloc.share(req.rid, pages)
            cache.touch(pages)
        if partial is not None:
            donor, _ = partial
            # the copy needs a destination page now, plus the transient
            # revive of an unreferenced donor; admission budgets this
            # (cache_probe cow_extra), but the bare-fit progress override
            # doesn't — degrade to a miss on the partial span instead of
            # raising OutOfPages mid-admission
            headroom = 1 + (0 if self.alloc.is_referenced(donor) else 1)
            if self.alloc.n_free >= headroom:
                pair = self.alloc.cow_partial(req.rid, donor)
                cache.touch([donor])
                self._apply_cow([pair])
                self.metrics.n_partial_hits += 1
            else:
                n = len(pages) * self.serve.page_size
        if n:
            self.metrics.req(req.rid).n_cached_tokens += n
            self.metrics.n_cached_tokens += n
        if self.sanitizer is not None:   # settle any preempt/resume promise
            self.sanitizer.note_resume(req, pages)
        return n

    def cache_insert(self, req: Request, n_committed: int,
                     final: bool = False) -> None:
        """Register ``req``'s committed-KV pages with the cache.

        Called after prefill work lands, at finish, and at preemption
        (scheduler) — the last one is what turns a preempted victim's
        recompute-on-resume into a remap of its own just-freed pages.
        Mid-flight inserts register full pages only (the tail page is
        still being written); ``final`` inserts — finish and preemption,
        where nothing will write into the tail again — also register the
        partial tail page at token granularity, so a future prompt that
        diverges inside it still reuses the matched span via COW.
        """
        if self.prefix_cache is None:
            return
        ps = self.serve.page_size
        n_full, rem = divmod(n_committed, ps)
        partial_tail = (final and rem > 0
                        and self.serve.prefix_cache_granularity == "token")
        n_pages = n_full + (1 if partial_tail else 0)
        if n_pages <= 0:
            return
        n_tokens = n_committed if partial_tail else n_full * ps
        tokens = (req.prompt + req.out_tokens)[:n_tokens]
        self.prefix_cache.insert(tokens, self.alloc.owned(req.rid)[:n_pages],
                                 allow_partial=partial_tail)

    def _apply_cow(self, pairs) -> None:
        """Materialize allocator copy-on-write decisions on the device
        pool (copy src page contents into the writer's private dst)."""
        if not pairs:
            return
        src = jnp.asarray([s for s, _ in pairs], jnp.int32)
        dst = jnp.asarray([d for _, d in pairs], jnp.int32)

        def copy(a):
            # tree-mapped: fp pools are bare [L, N, ps, KV_p, d] arrays,
            # int8 pools are {"q": codes, "s": scales} dicts — a COW copy
            # must move the scale sidecar WITH the codes
            return a.at[:, dst].set(a[:, src])

        self.k_pages = jax.tree.map(copy, self.k_pages)
        self.v_pages = jax.tree.map(copy, self.v_pages)
        if self.kv_quant is not None:
            for s, d in pairs:
                self.kv_quant.note_copy(s, d)

    def _refresh_cache_stats(self) -> None:
        if self.kv_quant is not None:
            self.metrics.n_quant_pages = self.kv_quant.n_quant_pages
        self._pages_shared_peak = max(self._pages_shared_peak,
                                      self.alloc.n_pages_shared)
        self.metrics.prefix_cache_stats = dict(
            enabled=int(self.prefix_cache is not None),
            n_reclaims=self.alloc.n_reclaims,
            n_cow=self.alloc.n_cow,
            n_partial_cow=self.alloc.n_partial_cow,
            n_shared_maps=self.alloc.n_shared_maps,
            pages_shared=self.alloc.n_pages_shared,
            pages_shared_peak=self._pages_shared_peak,
            n_reclaimable=(self.prefix_cache.n_reclaimable
                           if self.prefix_cache else 0),
            n_cached_pages=(self.prefix_cache.n_cached_pages
                            if self.prefix_cache else 0),
            n_evicted=(self.prefix_cache.n_evicted
                       if self.prefix_cache else 0),
        )

    # ------------------------------------------------------------- steps ---
    def step(self) -> List[TokenEvent]:
        self._events = []
        mode = self.serve.mode
        n_pre = self.metrics.n_preempt_events
        if mode == "sequential":
            kind = self._step_sequential()
        elif mode == "splitwiser":
            kind = self._step_timesliced()
        elif mode == "splitwiser_mps":
            kind = self._step_fused()
        elif mode == "chunked":
            kind = self._step_chunked()
        else:
            # ServeConfig.__post_init__ validates against SERVE_MODES, so
            # reaching here means a mode was registered without a step path
            raise RuntimeError(
                f"no step path for serve mode {mode!r}; SERVE_MODES and "
                "Engine.step() must be extended together")
        if kind == "idle" and self.metrics.n_preempt_events > n_pre:
            kind = "preempt"    # nothing dispatched, but evictions happened
        self.metrics.n_steps += 1
        self.metrics.step_kinds.append(kind)
        self.metrics.kv_usage_trace.append(self.alloc.usage())
        self._refresh_cache_stats()
        if self.sanitizer is not None:
            self.sanitizer.after_step(
                any(e.finish_reason is not None for e in self._events))
        return self._events

    # --- sequential: full-prompt prefill OR decode per step -----------------
    def _step_sequential(self) -> str:
        batch = self.sched.take_prefillable()
        if batch:
            self._do_full_prefill(batch)
            return "prefill"
        if any(self.slots) and self._do_decode():
            return "decode"
        return "idle"

    def _do_full_prefill(self, reqs: List[Request]):
        """Sequential-mode prefill: cache misses take the classic
        full-prompt path; cache hits map their shared pages and compute
        only the uncached suffix through the paged mixed kernel.  Two
        identical prompts admitted in the same batch both miss (the
        first's pages are only registered at commit) — the copy is
        cached for every later request."""
        if self.prefix_cache is None:
            self._prefill_full_batch(reqs)
            return
        hits, misses = [], []
        for r in reqs:
            n_cached = self._map_cached(r)
            (hits if n_cached else misses).append((r, n_cached))
        if misses:
            self._prefill_full_batch([r for r, _ in misses])
        if hits:
            self._prefill_suffix_batch(hits)

    def _prefill_full_batch(self, reqs: List[Request]):
        ps = self.serve.page_size
        t0 = self.now()
        S_pad = max(-(-max(len(r.prefill_tokens) for r in reqs) // ps) * ps, ps)
        Bp = len(reqs)
        tokens = np.zeros((Bp, S_pad), np.int32)
        lens = np.zeros((Bp,), np.int32)
        for i, r in enumerate(reqs):
            toks = r.prefill_tokens
            tokens[i, : len(toks)] = toks
            lens[i] = len(toks)
            m = self.metrics.req(r.rid)
            if m.t_prefill_start is None:
                m.t_prefill_start = t0
            self.metrics.n_prefill_tokens += len(toks)
        logits, (k, v) = self._prefill(self.params, jnp.asarray(tokens),
                                       jnp.asarray(lens))
        # commit contiguous KV into allocated pages
        n_per = S_pad // ps
        dest = np.full((Bp * n_per,), self.alloc.trash_page, np.int32)
        for i, r in enumerate(reqs):
            pages = self.alloc.alloc(r.rid, self.alloc.pages_needed(lens[i]))
            dest[i * n_per : i * n_per + len(pages)] = pages
        k_new = T.kv_to_pages(k, ps)
        v_new = T.kv_to_pages(v, ps)
        self.k_pages, self.v_pages = self._commit(
            self.k_pages, self.v_pages, k_new, v_new, jnp.asarray(dest))
        if self.kv_quant is not None:
            for r in reqs:       # before _emit_first_token may free them
                self.kv_quant.note_write(self.alloc.owned(r.rid))
        toks = self._sample_rows(logits, reqs)
        t1 = self.now()
        for i, r in enumerate(reqs):
            self.cache_insert(r, int(lens[i]))
            self._emit_first_token(r, int(toks[i]), int(lens[i]), t1)

    def _prefill_suffix_batch(self, hits: List[tuple]):
        """Prefill (request, n_cached) pairs from their first uncached
        token: hit pages are already mapped into ownership, the suffix
        chunk attends to them through the paged mixed kernel
        (``p_start > 0`` — with token-level reuse the start may sit
        mid-page, inside the COW-copied donor), and only suffix pages
        are freshly allocated."""
        ps = self.serve.page_size
        t0 = self.now()
        P = len(hits)
        suffixes = [r.prefill_tokens[n:] for r, n in hits]
        C = max(-(-max(len(s) for s in suffixes) // ps) * ps, ps)
        W = self.serve.max_pages_per_seq + 1   # +1 slack: padded chunk page
                                               # lookups may peek one past
        p_tokens = np.zeros((P, C), np.int32)
        p_start = np.zeros((P,), np.int32)
        p_lens = np.zeros((P,), np.int32)
        p_table = np.zeros((P, W), np.int32)
        for i, (r, n) in enumerate(hits):
            toks = suffixes[i]
            m = self.metrics.req(r.rid)
            if m.t_prefill_start is None:
                m.t_prefill_start = t0
            self.alloc.extend_to(r.rid, n + len(toks))
            self._apply_cow(self.alloc.prepare_write(r.rid, n, len(toks)))
            bt = self.alloc.owned(r.rid)
            p_table[i, : len(bt)] = bt
            p_tokens[i, : len(toks)] = toks
            p_start[i] = n
            p_lens[i] = len(toks)
            self.metrics.n_prefill_tokens += len(toks)
        mb = dict(
            p_tokens=jnp.asarray(p_tokens),
            p_table=jnp.asarray(p_table),
            p_start=jnp.asarray(p_start),
            p_lens=jnp.asarray(p_lens),
            d_tokens=jnp.zeros((0,), jnp.int32),
            d_table=jnp.zeros((0, W), jnp.int32),
            d_lens=jnp.zeros((0,), jnp.int32),
            d_active=jnp.zeros((0,), bool),
        )
        p_logits, _, (self.k_pages, self.v_pages), _ = self._mixed(
            self.params, mb, self.k_pages, self.v_pages)
        if self.kv_quant is not None:
            for r, _ in hits:    # hit pages were written by their donor;
                self.kv_quant.note_write(self.alloc.owned(r.rid))  # idempotent
        toks_out = self._sample_rows(p_logits, [r for r, _ in hits])
        t1 = self.now()
        for i, (r, n) in enumerate(hits):
            full_len = n + len(suffixes[i])
            self.cache_insert(r, full_len)
            self._emit_first_token(r, int(toks_out[i]), full_len, t1)

    def _emit_first_token(self, req: Request, tok: int, seq_len: int, t):
        """First token after a (re-)prefill; a resumed request keeps its
        original TTFT."""
        self.unregister_inflight(req.rid)   # prefill committed: twins now hit
        if self.sanitizer is not None:      # close the admission budget loop
            self.sanitizer.note_first_token(req.rid)
        m = self.metrics.req(req.rid)
        if m.t_first_token is None:
            m.t_first_token = t
        m.token_times.append(t)
        req.out_tokens.append(tok)
        m.n_generated = len(req.out_tokens)
        reason = self._finish_reason(req)
        self._record_event(req, tok, t, reason)
        if reason is not None:
            self._finish(req, t, reason, n_committed=seq_len)
            return
        free = next((i for i, s in enumerate(self.slots) if s is None), None)
        if free is None:
            raise RuntimeError(
                f"slot invariant violated: no free decode slot for rid "
                f"{req.rid} (max_batch={self.serve.max_batch}). Admission is "
                "bounded by free slots (take_prefillable / _compose_prefill "
                "backpressure), so an over-full prefill batch is an engine "
                "bug, not a capacity error.")
        self.slots[free] = _Slot(req=req, seq_len=seq_len, next_token=tok)
        bt = self.alloc.owned(req.rid)
        self.block_tables[free, :] = 0
        self.block_tables[free, : len(bt)] = bt

    def _finish_reason(self, req: Request) -> Optional[str]:
        """None while running, else "length" | "stop" (per-request params)."""
        if req.out_tokens and req.out_tokens[-1] in req.sampling.stop_set:
            return "stop"
        if len(req.out_tokens) >= req.sampling.max_new_tokens:
            return "length"
        return None

    def _finish(self, req: Request, t, reason: str, n_committed: int = 0):
        m = self.metrics.req(req.rid)
        m.t_done = t
        m.n_generated = len(req.out_tokens)
        m.finish_reason = reason
        # settle the SLO verdict: TTFT against the target, TBT against
        # the WORST inter-token gap; None (no deadline resolved) stays
        # out of the attainment fractions
        eff = self.effective_slo(req)
        m.slo_ok = slo_outcome(m.ttft, m.tbt_max, eff)
        if m.slo_ok is True:
            self.metrics.slo_attained += 1
        elif m.slo_ok is False:
            self.metrics.slo_missed += 1
        # register committed KV before freeing: the pages park on the
        # cache's reclaimable list and keep serving identical prefixes
        # (final: the partial tail page is reusable too)
        self.cache_insert(req, n_committed, final=True)
        self.alloc.free(req.rid)
        self._outputs.append(RequestOutput(
            rid=req.rid, prompt=list(req.prompt), tokens=list(req.out_tokens),
            finish_reason=reason, n_preempted=m.n_preempted,
            n_cached_tokens=m.n_cached_tokens,
            arrival=m.arrival, token_times=list(m.token_times), t_done=t,
            tenant=eff.tenant, slo_attained=m.slo_ok))

    def _record_event(self, req: Request, tok: int, t, reason: Optional[str]):
        self._events.append(TokenEvent(
            rid=req.rid, token=tok, index=len(req.out_tokens) - 1, t=t,
            first=len(req.out_tokens) == 1, finish_reason=reason))

    def _reserve_decode_pages(self):
        """Grow every active slot's page table for its next token,
        preempting younger requests under pressure.  A slot that cannot
        be served even after evicting every younger victim (older
        requests hold the pool) preempts itself.  With
        ``preempt_policy="none"`` the raw `extend_to` may raise
        OutOfPages — the seed crash, kept for comparison runs."""
        for i in range(len(self.slots)):
            s = self.slots[i]
            if s is None:
                continue
            if self.serve.preempt_policy != "none" and \
                    not self.sched.ensure_pages(s.req, s.seq_len + 1):
                self.sched.preempt("slot", i, reason="self")
                continue
            new = self.alloc.extend_to(s.req.rid, s.seq_len + 1)
            # COW a shared/cached tail page before the decode token's KV
            # scatters into it (no-op unless the page has other readers)
            pairs = self.alloc.prepare_write(s.req.rid, s.seq_len)
            self._apply_cow(pairs)
            if new or pairs:
                bt = self.alloc.owned(s.req.rid)
                self.block_tables[i, : len(bt)] = bt

    def _do_decode(self) -> bool:
        self._reserve_decode_pages()
        tokens, lens, active = self._decode_inputs()
        if not active.any():        # every slot was preempted
            return False
        logits, (self.k_pages, self.v_pages) = self._decode(
            self.params, jnp.asarray(tokens), self.k_pages, self.v_pages,
            jnp.asarray(self.block_tables), jnp.asarray(lens),
            jnp.asarray(active))
        self._advance_decode(logits, active, self.now())
        return True

    # --- splitwiser modes ----------------------------------------------------
    def _refill_streams(self):
        for r in self.sched.admit_streams():
            i = self.streams.index(None)
            # a cache hit maps shared pages and fast-forwards the stream
            # past the cached chunks: prefill starts at the first
            # uncached token (SARATHI-style streams skip cached work)
            n_cached = self._map_cached(r)
            self.streams[i] = _Stream(req=r, tokens=r.prefill_tokens,
                                      pos=n_cached)
            m = self.metrics.req(r.rid)
            if m.t_prefill_start is None:
                m.t_prefill_start = self.now()

    def _compose_prefill(self, plan: Optional[ChunkPlan] = None):
        """Build the prefill half of a mixed batch from the streams.

        A stream's final chunk is only scheduled when a decode slot is
        available for the request it completes (backpressure); a stream
        whose page extension cannot be satisfied this step — even after
        the scheduler evicts younger victims — simply skips its chunk
        and retries once pages free up.  Streams already composed this
        step are protected from eviction (their chunk is about to write
        into their pages).

        With a ``plan`` (chunked mode) each stream contributes exactly
        its planned token count — the static row width is the plan's cap
        (``chunk_tokens``) instead of ``prefill_chunk`` — and every
        composed chunk pre-commits its page consumption to the sanitizer
        (``note_chunk``): admission charged only the first chunk, so the
        budget-honesty check grows with the plan, not the prompt.  A
        skipped stream's planned tokens are *not* redistributed this
        round (conservative; the next plan re-carves).
        """
        P = self.serve.n_streams
        C = self.serve.prefill_chunk if plan is None else plan.cap
        p_tokens = np.zeros((P, C), np.int32)
        p_start = np.zeros((P,), np.int32)
        p_lens = np.zeros((P,), np.int32)
        chunks = []
        protect = set()
        free_slots = sum(s is None for s in self.slots)
        for i, st in enumerate(self.streams):
            if st is None:
                continue
            want = C if plan is None else plan.chunk_lens[i]
            n = min(want, len(st.tokens) - st.pos)
            if n <= 0:
                continue
            if st.pos + n >= len(st.tokens) and free_slots <= 0:
                continue                             # completing chunk, no slot
            if self.serve.preempt_policy != "none" and \
                    not self.sched.ensure_pages(st.req, st.pos + n + 1,
                                                protect=protect):
                continue
            if st.pos + n >= len(st.tokens):
                free_slots -= 1
            if plan is not None and self.sanitizer is not None:
                self.sanitizer.note_chunk(st.req.rid,
                                          self._chunk_charge(st, n))
            self.alloc.extend_to(st.req.rid, st.pos + n + 1)
            self._apply_cow(self.alloc.prepare_write(st.req.rid, st.pos, n))
            bt = self.alloc.owned(st.req.rid)
            self.stream_tables[i, :] = 0
            self.stream_tables[i, : len(bt)] = bt
            p_tokens[i, :n] = st.tokens[st.pos : st.pos + n]
            p_start[i] = st.pos
            p_lens[i] = n
            protect.add(st.req.rid)
            chunks.append((i, st, n))
        return p_tokens, p_start, p_lens, chunks

    def _chunk_charge(self, st: _Stream, n: int) -> int:
        """Upper bound on the free-pool pages ``st``'s next ``n``-token
        chunk may consume: fresh tail pages, plus a COW copy for every
        owned page in the chunk's write range that ``prepare_write``
        could copy (shared with another reader, or registered in the
        trie).  Computed BEFORE the chunk allocates, so the sanitizer's
        chunked-mode budget stays a real pre-commitment rather than a
        tautology."""
        owned = self.alloc.owned(st.req.rid)
        fresh = max(self.alloc.pages_needed(st.pos + n + 1) - len(owned), 0)
        ps = self.serve.page_size
        lo = st.pos // ps
        hi = min((st.pos + n - 1) // ps, len(owned) - 1)
        cow = sum(1 for p in owned[lo:hi + 1]
                  if self.alloc.ref_count(p) > 1
                  or (self.prefix_cache is not None
                      and self.prefix_cache.is_cached(p)))
        return fresh + cow

    def _advance_streams(self, chunks, p_logits, t):
        completing = [None] * len(self.streams)
        for i, st, n in chunks:
            if st.pos + n >= len(st.tokens):
                completing[i] = st.req
        toks = (self._sample_rows(p_logits, completing)
                if any(r is not None for r in completing) else None)
        for i, st, n in chunks:
            st.pos += n
            self.metrics.n_prefill_tokens += n
            if self.kv_quant is not None:
                # only pages the chunk actually covered — extend_to reserved
                # one token past the chunk, which may be an unwritten page
                self.kv_quant.note_write(self.alloc.owned(st.req.rid)
                                         [: self.alloc.pages_needed(st.pos)])
            self.cache_insert(st.req, st.pos)   # register landed full pages
            if st.pos >= len(st.tokens):
                self._emit_first_token(st.req, int(toks[i]), len(st.tokens), t)
                self.streams[i] = None

    def _decode_inputs(self):
        B = self.serve.max_batch
        tokens = np.zeros((B,), np.int32)
        lens = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            tokens[i] = s.next_token
            lens[i] = s.seq_len
            active[i] = True
        return tokens, lens, active

    def _dispatch_mixed(self, composed, with_decode: bool) -> bool:
        """Dispatch ONE mixed program over the composed prefill chunks
        and advance both halves on a single timestamp — the shared tail
        of the fused, time-sliced, and chunked step paths.

        ``with_decode=True`` (fused/chunked) packs every decode slot in:
        the decode arrays stay ``max_batch``-sized even when no slot is
        active, so the mode keeps one static program shape.
        ``with_decode=False`` (the time-sliced prefill phase) dispatches
        the same kernel phase-exclusively with zero-size decode arrays.
        Returns False when there was nothing to dispatch.
        """
        p_tokens, p_start, p_lens, chunks = composed
        if with_decode:
            d_tokens, d_lens, d_active = self._decode_inputs()
            if not chunks and not d_active.any():
                return False
            d_half = dict(
                d_tokens=jnp.asarray(d_tokens),
                d_table=jnp.asarray(self.block_tables),
                d_lens=jnp.asarray(d_lens),
                d_active=jnp.asarray(d_active),
            )
        else:
            if not chunks:
                return False
            Pmax = self.serve.max_pages_per_seq
            d_active = np.zeros((0,), bool)
            d_half = dict(
                d_tokens=jnp.zeros((0,), jnp.int32),
                d_table=jnp.zeros((0, Pmax), jnp.int32),
                d_lens=jnp.zeros((0,), jnp.int32),
                d_active=jnp.zeros((0,), bool),
            )
        mb = dict(
            p_tokens=jnp.asarray(p_tokens),
            p_table=jnp.asarray(self.stream_tables),
            p_start=jnp.asarray(p_start),
            p_lens=jnp.asarray(p_lens),
            **d_half,
        )
        p_logits, d_logits, (self.k_pages, self.v_pages), _ = self._mixed(
            self.params, mb, self.k_pages, self.v_pages)
        t = self.now()
        if d_active.size and d_active.any():
            self._advance_decode(d_logits, d_active, t)
        self._advance_streams(chunks, p_logits, t)
        return True

    def _step_fused(self) -> str:
        """splitwiser_mps: ONE program runs both phases (the contribution)."""
        self._refill_streams()
        # reserve decode pages BEFORE composing prefill: compose-time
        # eviction of an already-extended slot is safe (it just drops out
        # of the decode half), the reverse would dispatch a chunk into a
        # preempted stream's freed pages.
        self._reserve_decode_pages()
        if self._dispatch_mixed(self._compose_prefill(), with_decode=True):
            return "mixed"
        return "idle"

    def _step_timesliced(self) -> str:
        """splitwiser (no MPS): phases alternate as separate programs."""
        self._refill_streams()
        has_chunks = any(s is not None and s.pos < len(s.tokens)
                         for s in self.streams)
        has_decode = any(self.slots)
        do_prefill = has_chunks and (self._step_parity == 0 or not has_decode)
        self._step_parity ^= 1
        # phase-exclusive program: prefill chunks only (B=0 decode part);
        # when slot backpressure / page pressure filtered out every chunk,
        # don't dispatch an empty program — fall through to decode
        if do_prefill and self._dispatch_mixed(self._compose_prefill(),
                                               with_decode=False):
            return "prefill_chunk"
        if has_decode and self._do_decode():
            return "decode"
        return "idle"

    def _step_chunked(self) -> str:
        """chunked: the planner packs the round, the engine dispatches it.

        Every runnable decode token rides in every round (never starved,
        never stalled behind a prompt); the planner carves the remaining
        ``chunk_tokens`` budget over the in-flight prefill streams.  One
        mixed dispatch per round — a 2k-token prompt becomes a train of
        budget-bounded chunks interleaved with live decodes, so tail TBT
        is bounded by the chunk budget instead of the prompt length."""
        self._refill_streams()
        self._reserve_decode_pages()
        n_decode = sum(s is not None for s in self.slots)
        remaining = [0 if st is None else max(len(st.tokens) - st.pos, 0)
                     for st in self.streams]
        plan = self.planner.plan(remaining, n_decode,
                                 self._stream_priorities())
        if self.sanitizer is not None:
            self.sanitizer.note_plan(plan, remaining, n_decode)
        composed = self._compose_prefill(plan)
        if not self._dispatch_mixed(composed, with_decode=True):
            return "idle"
        chunks = composed[3]
        self.metrics.n_chunks += len(chunks)
        packed = sum(n for _, _, n in chunks) + n_decode
        hist = self.metrics.packed_tokens_hist
        hist[packed] = hist.get(packed, 0) + 1
        return "mixed"

    def _stream_priorities(self) -> Optional[List[Optional[float]]]:
        """Per-stream carve urgencies for the chunk planner: tenant-
        weighted TTFT slack, ascending = more urgent (core/slo.py).
        None when no in-flight prefill carries a TTFT deadline — the
        deadline-free path stays byte-identical (cursor round-robin, no
        clock read; one ``now()`` read per round otherwise).  Weight
        scaling is sign-aware so a heavier tenant is *always* more
        urgent at equal raw slack: positive slack shrinks by the weight,
        overdue (negative) slack grows by it."""
        effs = [None if st is None else self.effective_slo(st.req)
                for st in self.streams]
        if not any(e is not None and e.ttft_target is not None for e in effs):
            return None
        t_now = self.now()
        out: List[Optional[float]] = []
        for st, e in zip(self.streams, effs):
            if e is None or e.ttft_target is None:
                out.append(None)
                continue
            slack = (st.req.arrival or 0.0) + e.ttft_target - t_now
            out.append(slack / e.weight if slack >= 0 else slack * e.weight)
        return out

    def _advance_decode(self, d_logits, d_active, t):
        rows = [s.req if (s is not None and d_active[i]) else None
                for i, s in enumerate(self.slots)]
        if not any(r is not None for r in rows):
            return
        toks = self._sample_rows(d_logits, rows)
        for i, s in enumerate(self.slots):
            if s is None or not d_active[i]:
                continue
            tok = int(toks[i])
            s.req.out_tokens.append(tok)
            s.seq_len += 1
            if self.kv_quant is not None:
                # the decode token's KV landed on the tail page (position
                # seq_len-1); register before a finish can free the pages
                tail = (s.seq_len - 1) // self.serve.page_size
                self.kv_quant.note_write([self.alloc.owned(s.req.rid)[tail]])
            m = self.metrics.req(s.req.rid)
            m.token_times.append(t)
            m.n_generated = len(s.req.out_tokens)
            reason = self._finish_reason(s.req)
            self._record_event(s.req, tok, t, reason)
            if reason is not None:
                self._finish(s.req, t, reason, n_committed=s.seq_len)
                self.slots[i] = None
            else:
                s.next_token = tok

    # ---------------------------------------------------------------- misc -
    def _sample_rows(self, logits, reqs: List[Optional[Request]]):
        """Sample one token per row of ``logits`` using each aligned
        request's own SamplingParams (None rows are inactive padding:
        greedy over garbage, discarded by the caller).  Row i's PRNG
        stream is (seed, rid, len(out_tokens)) — the index of the token
        being sampled — so results don't depend on batch composition,
        engine mode, or preemption history."""
        if all(r is None or r.sampling.temperature <= 0.0 for r in reqs):
            return np.asarray(self._greedy(logits))    # all-greedy fast path
        B = logits.shape[0]
        temp = np.zeros((B,), np.float32)
        top_k = np.zeros((B,), np.int32)
        top_p = np.ones((B,), np.float32)
        seed = np.zeros((B,), np.int32)
        rid = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        for i, r in enumerate(reqs):
            if r is None:
                continue
            sp = r.sampling
            temp[i] = sp.temperature
            top_k[i] = sp.top_k
            top_p[i] = sp.top_p
            seed[i] = sp.seed
            rid[i] = r.rid
            pos[i] = len(r.out_tokens)
        return np.asarray(self._sample(
            logits, jnp.asarray(temp), jnp.asarray(top_k), jnp.asarray(top_p),
            jnp.asarray(seed), jnp.asarray(rid), jnp.asarray(pos)))
