"""Pluggable scheduling policies: admission, eviction, preemption.

Splitwiser's constrained-resource premise makes the three scheduling
decisions — who gets admitted, which cached KV pages get reclaimed, who
gets preempted — the dominant lever on throughput and TTFT once kernels
and the shared-prefix cache are in place (SARATHI and Lin et al.'s
single-moderate-GPU study both put the policy choice, not kernel speed,
on the frontier).  This module makes each decision a first-class,
swappable object; ``core/scheduler.py`` keeps only the mechanism
(budgets, eligibility, queue surgery).

Invariant shared by every policy: policies change *when* work happens,
never *what* is computed.  Sampling is batch/mode/history-independent
(``(seed, rid, pos)`` PRNG streams), so greedy and sampled token streams
are bit-identical across every ``admission x eviction x preempt``
combination (``tests/test_policies.py``).

Admission (:class:`AdmissionPolicy` — ``serve.admission_policy``)
    ``fcfs``        pop the waiting queue in arrival order (seed behaviour).
    ``cache_aware`` each admission round, order the waiting queue so
                    requests whose prefixes are *resident* in the prefix
                    cache are co-scheduled first (their pages remap instead
                    of recompute), and *hold back* a request whose prefix
                    is currently being prefilled by an in-flight request
                    (the engine's in-flight registry): it waits one round
                    and hits, instead of double-missing alongside the
                    twin that is about to insert its pages.  Every round
                    a request is passed over adds
                    ``serve.admission_age_weight`` to its score, bounding
                    the worst-case wait of a cold-prefix request under a
                    hot-template stream (no starvation).
    ``deadline``    earliest-deadline-first by TTFT *slack*: deadline
                    (``arrival + ttft_target``, resolved through the
                    request's tenant tier — ``core/slo.py``) minus the
                    current clock minus a predicted completion cost
                    (``serve.slo_page_cost`` per page the admission
                    would allocate, via the round-memoized
                    ``Scheduler.probe``/``admission_pages`` predictor).
                    Requests with no deadline carry infinite slack and
                    sort FCFS among themselves *after* every
                    deadline-bearing request; a queue with no deadlines
                    at all degenerates to exact FCFS with zero clock
                    reads.  ``holds`` enforces per-tenant in-flight
                    token quotas (``TenantTier.quota_tokens``): a
                    tenant at quota has its next request skipped for
                    the round — the burst queues behind its own quota
                    instead of starving other tenants — except that a
                    single over-quota request on an otherwise idle
                    tenant is admitted (progress guarantee: quotas
                    bound concurrency, they never wedge a tenant).

Preemption gains the matching arm:
    ``deadline``    maximum-slack victim: the binding deadline is TTFT
                    while no token has been emitted, then TBT from the
                    last emitted token; the candidate with the most
                    slack (no-deadline candidates rank as infinite, so
                    they are preempted first) is evicted, tie-broken by
                    the ``cache_aware`` resume-safe fraction and then
                    latest arrival — a deadline-critical request is
                    never evicted while a slack-rich one runs, and with
                    no deadlines anywhere the choice is bit-identical
                    to ``cache_aware``.

Eviction (:class:`EvictionPolicy` — ``serve.eviction_policy``)
    Ranks the prefix cache's reclaimable zero-ref *leaf* pages; the
    lowest-ranked leaf is stripped first when the free list runs dry.
    ``lru``   least-recently-hit leaf first (today's default).
    ``fifo``  oldest-inserted leaf first.
    ``cost``  cheapest-to-recompute leaf first, by the per-page
              recompute-FLOPs proxy ``PrefixCache.page_cost``: a deep
              page's recompute replays attention over its whole prefix
              (expensive — keep), a shallow long-tail leaf is nearly
              free to rebuild (evict).  Descendant counts weight pages
              that anchor large cached subtrees.

Preemption (:class:`PreemptPolicy` — ``serve.preempt_policy``)
    Picks one victim among the mechanism's eligible candidates (running
    requests strictly younger than the needy one whose eviction actually
    frees pages).
    ``latest``      latest-arrival victim (today's default).
    ``cache_aware`` victim whose committed KV would mostly *survive* its
                    own eviction — pages shared with another live request
                    keep serving hits, so the resume is a block-table
                    remap, not a recompute (``Engine.resume_safe_pages``).
                    Tie-broken by latest arrival.
    ``none``        preemption disabled (seed crash-on-exhaustion arm);
                    handled by the scheduler, no policy object.

Registries map config strings to classes; ``ServeConfig.__post_init__``
validates against them so a typo fails at config time, not mid-serve.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

from repro.core.slo import request_footprint


# --------------------------------------------------------------- admission --
class AdmissionPolicy:
    """Orders (and may hold back) the waiting queue for one admission round.

    ``order`` ranks the round's candidates once; ``holds`` is consulted
    per candidate *inside* the admission loop — after earlier candidates
    of the same round have registered their in-flight prefills — so a
    policy can defer a request based on what this very round has just
    admitted (the double-miss case).  A held request is skipped, not a
    head-of-line block.
    """

    name = "base"

    def order(self, sched) -> List:
        raise NotImplementedError

    def holds(self, sched, req) -> bool:
        return False


class FCFSAdmission(AdmissionPolicy):
    """Arrival order, head-of-line blocking — the seed behaviour."""

    name = "fcfs"

    def order(self, sched) -> List:
        return list(sched.waiting)


class CacheAwareAdmission(AdmissionPolicy):
    """Co-schedule resident prefixes; hold twins of in-flight prefills.

    ``order``: resident-hit pages sort first (descending, one trie walk
    per waiting request via ``Engine.cache_probe``), FCFS
    ``(arrival, rid)`` breaks ties — so a zero-hit queue degenerates to
    exact FCFS.  Each round a request waits adds
    ``serve.admission_age_weight`` pages to its effective score
    (``Scheduler.wait_rounds``), so a cold-prefix request passed over by
    a sustained hot-template stream eventually outranks the hits and its
    worst-case wait is bounded — with weight 0 the order is pure
    hit-first (and a cold request CAN starve under an open-loop hot
    stream).  ``holds``: a request is skipped for the round when some
    in-flight prefill (including one admitted earlier in this same
    round) will cache strictly more of its prefix than is resident now —
    admitting it would double-miss work its twin is already computing.
    Holding cannot deadlock: an in-flight entry exists only while its
    owner is actively prefilling (unregistered at completion and at
    preemption), so the held request is reconsidered next round against
    a warmer cache.
    """

    name = "cache_aware"

    def order(self, sched) -> List:
        w = sched.serve.admission_age_weight
        ranked = [(-(sched.probe(r)[0] + w * sched.wait_rounds(r.rid)),
                   r.arrival, r.rid, r)
                  for r in sched.waiting]
        ranked.sort(key=lambda t: t[:3])
        out = [t[3] for t in ranked]
        if [r.rid for r in out] != [r.rid for r in sched.waiting]:
            sched.metrics.bump("admission_reorders")
        return out

    def holds(self, sched, req) -> bool:
        # the in-flight scan stays live (same-round admits register), only
        # the trie probe is round-memoized
        if sched.eng.inflight_hit_pages(req) > sched.probe(req)[0]:
            sched.metrics.bump("admission_holds")
            return True
        return False


class DeadlineAdmission(AdmissionPolicy):
    """Slack-ranked (EDF) admission with per-tenant token quotas.

    ``order``: each waiting request's TTFT slack is its deadline
    (``arrival + ttft_target``, tier-resolved) minus the clock, minus
    ``serve.slo_page_cost`` engine-seconds per page the admission would
    allocate (the same ``probe``/``admission_pages`` arithmetic the
    watermark budget uses, round-memoized, so ranking adds no extra trie
    walks).  Least slack first; infinite-slack (deadline-free) requests
    keep FCFS order among themselves at the back.  The clock is read at
    most once per round, and not at all when no waiting request carries
    a TTFT deadline — a deadline-free queue is byte-for-byte FCFS, which
    is what makes the no-deadline bit-identity guarantee hold trivially.

    ``holds``: a request whose tenant already holds ``quota_tokens`` or
    more in-flight footprint tokens (prompt + full ``max_new_tokens``
    grant, across slots, streams, and this round's earlier admits) is
    skipped for the round.  The check is ``inflight > 0 and inflight +
    footprint > quota``: an oversized request on an idle tenant still
    admits, so a quota can bound a tenant's concurrency but never wedge
    it, and a held burst drains as its own requests finish (no
    cross-tenant dependency, no deadlock).
    """

    name = "deadline"

    def order(self, sched) -> List:
        eng = sched.eng
        effs = [(r, eng.effective_slo(r)) for r in sched.waiting]
        if all(eff.ttft_target is None for _, eff in effs):
            return list(sched.waiting)
        t_now = eng.now()
        cost = sched.serve.slo_page_cost
        ranked = []
        for r, eff in effs:
            if eff.ttft_target is None:
                slack = math.inf
            else:
                slack = (r.arrival or 0.0) + eff.ttft_target - t_now
                if cost:
                    n_hit, n_free_hit, cow_extra = sched.probe(r)
                    slack -= cost * sched.admission_pages(
                        r, free_cached=n_free_hit, cow_extra=cow_extra,
                        n_hit=n_hit)
            ranked.append((slack, r.arrival, r.rid, r))
        ranked.sort(key=lambda t: t[:3])
        out = [t[3] for t in ranked]
        if [r.rid for r in out] != [r.rid for r in sched.waiting]:
            sched.metrics.bump("admission_reorders")
        return out

    def holds(self, sched, req) -> bool:
        eff = sched.eng.effective_slo(req)
        if eff.quota_tokens is None:
            return False
        inflight = sched.tenant_inflight_tokens(eff.tenant)
        if inflight > 0 and \
                inflight + request_footprint(req) > eff.quota_tokens:
            sched.metrics.bump("quota_holds")
            return True
        return False


# ---------------------------------------------------------------- eviction --
class EvictionPolicy:
    """Ranks reclaimable prefix-cache leaves; the min-rank leaf is evicted."""

    name = "base"

    def rank(self, node, cache):
        raise NotImplementedError


class LRUEviction(EvictionPolicy):
    name = "lru"

    def rank(self, node, cache):
        return node.last_used


class FIFOEviction(EvictionPolicy):
    name = "fifo"

    def rank(self, node, cache):
        return node.nid


class CostEviction(EvictionPolicy):
    """Evict the page whose recompute is cheapest (FLOPs-saved-per-page
    cost model): rank by ``PrefixCache.page_cost`` — depth-weighted
    attention replay plus descendant fan-out — with LRU as tie-break."""

    name = "cost"

    def rank(self, node, cache):
        return (cache.page_cost(node.page), node.last_used)


# -------------------------------------------------------------- preemption --
class PreemptPolicy:
    """Chooses one eviction victim from the mechanism's candidates.

    ``candidates`` rows are ``(kind, index, req, committed)`` — container
    kind ("slot"/"stream"), position, the running request, and its
    committed-KV token count.  Returns ``(kind, index)`` or None.
    """

    name = "base"

    def select(self, candidates: List[Tuple], eng) -> Optional[Tuple[str, int]]:
        raise NotImplementedError


class LatestPreempt(PreemptPolicy):
    """Latest-arrival victim: arrival order stays a total priority order,
    so the oldest request always makes progress (termination argument in
    ``core/scheduler.py``)."""

    name = "latest"

    def select(self, candidates, eng):
        if not candidates:
            return None
        kind, i, _, _ = max(candidates, key=lambda c: (c[2].arrival, c[2].rid))
        return kind, i


class CacheAwarePreempt(PreemptPolicy):
    """Prefer the victim whose committed KV mostly survives its eviction.

    ``Engine.resume_safe_pages`` counts the victim's committed full pages
    that are cached *and* referenced by another live request — those keep
    serving after the victim's refcounts drop, so its resume re-hits them
    (remap ≈ free) instead of recomputing the whole prefix.  The score is
    the surviving fraction of committed pages; latest ``(arrival, rid)``
    breaks ties, so with a cold cache this degenerates to ``latest``.
    """

    name = "cache_aware"

    def select(self, candidates, eng):
        if not candidates:
            return None
        best, best_key, best_safe = None, None, 0
        for kind, i, req, committed in candidates:
            n_safe = eng.resume_safe_pages(req, committed)
            frac = n_safe / max(eng.alloc.pages_needed(committed), 1)
            key = (frac, req.arrival, req.rid)
            if best_key is None or key > best_key:
                best, best_key, best_safe = (kind, i), key, n_safe
        if best_safe > 0:
            eng.metrics.bump("cheap_preemptions")
        return best


class DeadlinePreempt(PreemptPolicy):
    """Maximum-slack victim: never evict a deadline-critical request
    while a slack-rich one runs.

    Each candidate's binding deadline is TTFT (``arrival +
    ttft_target``) while it has emitted no token, then TBT
    (``last token time + tbt_target``) — both tier-resolved; a request
    with no applicable target has infinite slack and is preferred as a
    victim.  Ties (notably the all-infinite no-deadline case) fall back
    to the ``cache_aware`` resume-safe fraction and then latest
    ``(arrival, rid)``, so with no deadlines anywhere the selection is
    bit-identical to ``cache_aware`` (and to ``latest`` on a cold
    cache).  The clock is read once, and only when some candidate
    actually carries a deadline.  Bumps ``deadline_spared_preemptions``
    when a tighter-slack candidate was passed over in favour of the
    chosen victim (the counter that proves the policy changed an
    outcome).
    """

    name = "deadline"

    def select(self, candidates, eng):
        if not candidates:
            return None
        effs = [eng.effective_slo(req) for _, _, req, _ in candidates]
        t_now = eng.now() if any(e.has_deadline for e in effs) else 0.0
        best, best_key, min_slack = None, None, math.inf
        for (kind, i, req, committed), eff in zip(candidates, effs):
            m = eng.metrics.req(req.rid)
            if m.t_first_token is None:
                deadline = ((req.arrival or 0.0) + eff.ttft_target
                            if eff.ttft_target is not None else math.inf)
            else:
                last = m.token_times[-1] if m.token_times \
                    else m.t_first_token
                deadline = (last + eff.tbt_target
                            if eff.tbt_target is not None else math.inf)
            slack = deadline - t_now
            min_slack = min(min_slack, slack)
            n_safe = eng.resume_safe_pages(req, committed)
            frac = n_safe / max(eng.alloc.pages_needed(committed), 1)
            key = (slack, frac, req.arrival, req.rid)
            if best_key is None or key > best_key:
                best, best_key = (kind, i), key
        if best_key is not None and min_slack < best_key[0]:
            eng.metrics.bump("deadline_spared_preemptions")
        return best


# -------------------------------------------------------------- registries --
ADMISSION_POLICIES = {p.name: p for p in (FCFSAdmission, CacheAwareAdmission,
                                          DeadlineAdmission)}
EVICTION_POLICIES = {p.name: p for p in (LRUEviction, FIFOEviction,
                                         CostEviction)}
# "none" disables preemption entirely (seed arm); it is a valid config
# value but has no policy object — the scheduler short-circuits it.
PREEMPT_POLICIES = {p.name: p for p in (LatestPreempt, CacheAwarePreempt,
                                        DeadlinePreempt)}


def _make(registry, kind: str, name: str):
    if name not in registry:
        raise ValueError(f"unknown {kind} {name!r}; expected one of "
                         f"{', '.join(sorted(registry))}")
    return registry[name]()


def make_admission(name: str) -> AdmissionPolicy:
    return _make(ADMISSION_POLICIES, "admission_policy", name)


def make_eviction(name: str) -> EvictionPolicy:
    return _make(EVICTION_POLICIES, "eviction_policy", name)


def make_preempt(name: str) -> Optional[PreemptPolicy]:
    if name == "none":
        return None
    return _make(PREEMPT_POLICIES, "preempt_policy", name)
