"""Pluggable scheduling policies: admission, eviction, preemption.

Splitwiser's constrained-resource premise makes the three scheduling
decisions — who gets admitted, which cached KV pages get reclaimed, who
gets preempted — the dominant lever on throughput and TTFT once kernels
and the shared-prefix cache are in place (SARATHI and Lin et al.'s
single-moderate-GPU study both put the policy choice, not kernel speed,
on the frontier).  This module makes each decision a first-class,
swappable object; ``core/scheduler.py`` keeps only the mechanism
(budgets, eligibility, queue surgery).

Invariant shared by every policy: policies change *when* work happens,
never *what* is computed.  Sampling is batch/mode/history-independent
(``(seed, rid, pos)`` PRNG streams), so greedy and sampled token streams
are bit-identical across every ``admission x eviction x preempt``
combination (``tests/test_policies.py``).

Admission (:class:`AdmissionPolicy` — ``serve.admission_policy``)
    ``fcfs``        pop the waiting queue in arrival order (seed behaviour).
    ``cache_aware`` each admission round, order the waiting queue so
                    requests whose prefixes are *resident* in the prefix
                    cache are co-scheduled first (their pages remap instead
                    of recompute), and *hold back* a request whose prefix
                    is currently being prefilled by an in-flight request
                    (the engine's in-flight registry): it waits one round
                    and hits, instead of double-missing alongside the
                    twin that is about to insert its pages.  Every round
                    a request is passed over adds
                    ``serve.admission_age_weight`` to its score, bounding
                    the worst-case wait of a cold-prefix request under a
                    hot-template stream (no starvation).

Eviction (:class:`EvictionPolicy` — ``serve.eviction_policy``)
    Ranks the prefix cache's reclaimable zero-ref *leaf* pages; the
    lowest-ranked leaf is stripped first when the free list runs dry.
    ``lru``   least-recently-hit leaf first (today's default).
    ``fifo``  oldest-inserted leaf first.
    ``cost``  cheapest-to-recompute leaf first, by the per-page
              recompute-FLOPs proxy ``PrefixCache.page_cost``: a deep
              page's recompute replays attention over its whole prefix
              (expensive — keep), a shallow long-tail leaf is nearly
              free to rebuild (evict).  Descendant counts weight pages
              that anchor large cached subtrees.

Preemption (:class:`PreemptPolicy` — ``serve.preempt_policy``)
    Picks one victim among the mechanism's eligible candidates (running
    requests strictly younger than the needy one whose eviction actually
    frees pages).
    ``latest``      latest-arrival victim (today's default).
    ``cache_aware`` victim whose committed KV would mostly *survive* its
                    own eviction — pages shared with another live request
                    keep serving hits, so the resume is a block-table
                    remap, not a recompute (``Engine.resume_safe_pages``).
                    Tie-broken by latest arrival.
    ``none``        preemption disabled (seed crash-on-exhaustion arm);
                    handled by the scheduler, no policy object.

Registries map config strings to classes; ``ServeConfig.__post_init__``
validates against them so a typo fails at config time, not mid-serve.
"""
from __future__ import annotations

from typing import List, Optional, Tuple


# --------------------------------------------------------------- admission --
class AdmissionPolicy:
    """Orders (and may hold back) the waiting queue for one admission round.

    ``order`` ranks the round's candidates once; ``holds`` is consulted
    per candidate *inside* the admission loop — after earlier candidates
    of the same round have registered their in-flight prefills — so a
    policy can defer a request based on what this very round has just
    admitted (the double-miss case).  A held request is skipped, not a
    head-of-line block.
    """

    name = "base"

    def order(self, sched) -> List:
        raise NotImplementedError

    def holds(self, sched, req) -> bool:
        return False


class FCFSAdmission(AdmissionPolicy):
    """Arrival order, head-of-line blocking — the seed behaviour."""

    name = "fcfs"

    def order(self, sched) -> List:
        return list(sched.waiting)


class CacheAwareAdmission(AdmissionPolicy):
    """Co-schedule resident prefixes; hold twins of in-flight prefills.

    ``order``: resident-hit pages sort first (descending, one trie walk
    per waiting request via ``Engine.cache_probe``), FCFS
    ``(arrival, rid)`` breaks ties — so a zero-hit queue degenerates to
    exact FCFS.  Each round a request waits adds
    ``serve.admission_age_weight`` pages to its effective score
    (``Scheduler.wait_rounds``), so a cold-prefix request passed over by
    a sustained hot-template stream eventually outranks the hits and its
    worst-case wait is bounded — with weight 0 the order is pure
    hit-first (and a cold request CAN starve under an open-loop hot
    stream).  ``holds``: a request is skipped for the round when some
    in-flight prefill (including one admitted earlier in this same
    round) will cache strictly more of its prefix than is resident now —
    admitting it would double-miss work its twin is already computing.
    Holding cannot deadlock: an in-flight entry exists only while its
    owner is actively prefilling (unregistered at completion and at
    preemption), so the held request is reconsidered next round against
    a warmer cache.
    """

    name = "cache_aware"

    def order(self, sched) -> List:
        w = sched.serve.admission_age_weight
        ranked = [(-(sched.probe(r)[0] + w * sched.wait_rounds(r.rid)),
                   r.arrival, r.rid, r)
                  for r in sched.waiting]
        ranked.sort(key=lambda t: t[:3])
        out = [t[3] for t in ranked]
        if [r.rid for r in out] != [r.rid for r in sched.waiting]:
            sched.metrics.bump("admission_reorders")
        return out

    def holds(self, sched, req) -> bool:
        # the in-flight scan stays live (same-round admits register), only
        # the trie probe is round-memoized
        if sched.eng.inflight_hit_pages(req) > sched.probe(req)[0]:
            sched.metrics.bump("admission_holds")
            return True
        return False


# ---------------------------------------------------------------- eviction --
class EvictionPolicy:
    """Ranks reclaimable prefix-cache leaves; the min-rank leaf is evicted."""

    name = "base"

    def rank(self, node, cache):
        raise NotImplementedError


class LRUEviction(EvictionPolicy):
    name = "lru"

    def rank(self, node, cache):
        return node.last_used


class FIFOEviction(EvictionPolicy):
    name = "fifo"

    def rank(self, node, cache):
        return node.nid


class CostEviction(EvictionPolicy):
    """Evict the page whose recompute is cheapest (FLOPs-saved-per-page
    cost model): rank by ``PrefixCache.page_cost`` — depth-weighted
    attention replay plus descendant fan-out — with LRU as tie-break."""

    name = "cost"

    def rank(self, node, cache):
        return (cache.page_cost(node.page), node.last_used)


# -------------------------------------------------------------- preemption --
class PreemptPolicy:
    """Chooses one eviction victim from the mechanism's candidates.

    ``candidates`` rows are ``(kind, index, req, committed)`` — container
    kind ("slot"/"stream"), position, the running request, and its
    committed-KV token count.  Returns ``(kind, index)`` or None.
    """

    name = "base"

    def select(self, candidates: List[Tuple], eng) -> Optional[Tuple[str, int]]:
        raise NotImplementedError


class LatestPreempt(PreemptPolicy):
    """Latest-arrival victim: arrival order stays a total priority order,
    so the oldest request always makes progress (termination argument in
    ``core/scheduler.py``)."""

    name = "latest"

    def select(self, candidates, eng):
        if not candidates:
            return None
        kind, i, _, _ = max(candidates, key=lambda c: (c[2].arrival, c[2].rid))
        return kind, i


class CacheAwarePreempt(PreemptPolicy):
    """Prefer the victim whose committed KV mostly survives its eviction.

    ``Engine.resume_safe_pages`` counts the victim's committed full pages
    that are cached *and* referenced by another live request — those keep
    serving after the victim's refcounts drop, so its resume re-hits them
    (remap ≈ free) instead of recomputing the whole prefix.  The score is
    the surviving fraction of committed pages; latest ``(arrival, rid)``
    breaks ties, so with a cold cache this degenerates to ``latest``.
    """

    name = "cache_aware"

    def select(self, candidates, eng):
        if not candidates:
            return None
        best, best_key, best_safe = None, None, 0
        for kind, i, req, committed in candidates:
            n_safe = eng.resume_safe_pages(req, committed)
            frac = n_safe / max(eng.alloc.pages_needed(committed), 1)
            key = (frac, req.arrival, req.rid)
            if best_key is None or key > best_key:
                best, best_key, best_safe = (kind, i), key, n_safe
        if best_safe > 0:
            eng.metrics.bump("cheap_preemptions")
        return best


# -------------------------------------------------------------- registries --
ADMISSION_POLICIES = {p.name: p for p in (FCFSAdmission, CacheAwareAdmission)}
EVICTION_POLICIES = {p.name: p for p in (LRUEviction, FIFOEviction,
                                         CostEviction)}
# "none" disables preemption entirely (seed arm); it is a valid config
# value but has no policy object — the scheduler short-circuits it.
PREEMPT_POLICIES = {p.name: p for p in (LatestPreempt, CacheAwarePreempt)}


def _make(registry, kind: str, name: str):
    if name not in registry:
        raise ValueError(f"unknown {kind} {name!r}; expected one of "
                         f"{', '.join(sorted(registry))}")
    return registry[name]()


def make_admission(name: str) -> AdmissionPolicy:
    return _make(ADMISSION_POLICIES, "admission_policy", name)


def make_eviction(name: str) -> EvictionPolicy:
    return _make(EVICTION_POLICIES, "eviction_policy", name)


def make_preempt(name: str) -> Optional[PreemptPolicy]:
    if name == "none":
        return None
    return _make(PREEMPT_POLICIES, "preempt_policy", name)
