# The paper's primary contribution: the Splitwiser phase-splitting
# serving engine (scheduler + paged KV + mixed batching + metrics).
from repro.core.kv_cache import PageAllocator, OutOfPages
from repro.core.metrics import RequestMetrics, EngineMetrics
from repro.core.scheduler import Scheduler
