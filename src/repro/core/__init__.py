# The paper's primary contribution: the Splitwiser phase-splitting
# serving engine (scheduler + paged KV + mixed batching + metrics) behind
# a vLLM-shaped request/response API.
from repro.core.kv_cache import OutOfPages, PageAllocator
from repro.core.metrics import EngineMetrics, RequestMetrics
from repro.core.outputs import RequestOutput, TokenEvent
from repro.core.planner import ChunkPlan, ChunkPlanner
from repro.core.prefix_cache import PrefixCache
from repro.core.sampler import SamplingParams, sample_tokens
from repro.core.scheduler import Scheduler

__all__ = [
    "ChunkPlan", "ChunkPlanner", "EngineMetrics", "OutOfPages",
    "PageAllocator", "PrefixCache", "RequestMetrics", "RequestOutput",
    "SamplingParams", "Scheduler", "TokenEvent", "sample_tokens",
]
