"""Jit-dispatch sentinel: prove the serving hot path stays compiled-once.

Splitwiser's chunked scheduling only delivers flat compute intensity if
the jitted step callables (`_prefill`/`_commit`/`_decode`/`_mixed` and
the samplers) compile once per static shape and then dispatch from
cache.  A Python-level bug — a shape that varies per call, a static arg
rebuilt each step, a jit wrapper constructed inside the loop — silently
turns every step into an XLA compile, and wall-clock benchmarks are the
only thing that would notice.  This module makes recompilation a
first-class, checkable signal:

* :class:`DispatchSentinel` wraps jitted callables and counts
  compilations per callable.  The primary probe is the wrapped
  function's ``_cache_size()`` (jax's per-callable compile-cache entry
  count) sampled around each call; when the probe is unavailable (plain
  callables, older jax) it falls back to tracking distinct duck-typed
  argument signatures (shape/dtype for array-likes).
* A **storm guard** on step-loop callables raises
  :class:`InvariantViolation` (invariant ``"jit_dispatch"``) when
  compile density stays pathological — ≥ ``storm_threshold`` compiles in
  the last ``storm_window`` calls once the window has filled.  Callables
  with legitimate shape diversity (prefill batches vary with workload)
  are wrapped with ``storm_guard=False`` and only counted.
* :meth:`mark_warm` snapshots per-callable compile counts after warmup;
  :meth:`check` then fails when post-warmup recompiles exceed a budget
  (default 0: the hot path must be compiled-once).  CI tier-1 exports
  ``REPRO_DISPATCH_SENTINEL=1`` so an accidental recompile in the step
  loop fails the build; ``benchmarks/sanitizer_overhead.py`` reports the
  counts per sanitize level.

Stdlib-only imports: the sentinel wraps callables handed to it and never
imports jax itself, so ``repro.analysis`` stays importable in the
jax-less lint/CI contexts.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Dict, Optional

from repro.analysis.invariants import InvariantViolation

STORM_WINDOW = 32      # calls in the rolling compile-density window
STORM_THRESHOLD = 16   # compiles within the window that constitute a storm


def _signature(x: Any) -> Any:
    """Duck-typed static signature: shape/dtype for array-likes, value
    identity for Python scalars, recursive over containers."""
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is not None and dtype is not None:
        return ("arr", tuple(shape), str(dtype))
    if isinstance(x, dict):
        return ("dict",) + tuple(sorted((k, _signature(v))
                                        for k, v in x.items()))
    if isinstance(x, (list, tuple)):
        return ("seq",) + tuple(_signature(v) for v in x)
    return ("py", type(x).__name__, repr(x)[:32])


class CallableStats:
    """Per-wrapped-callable dispatch accounting."""

    __slots__ = ("name", "storm_guard", "n_calls", "n_compiles",
                 "warm_compiles", "recent", "signatures")

    def __init__(self, name: str, storm_guard: bool, window: int):
        self.name = name
        self.storm_guard = storm_guard
        self.n_calls = 0
        self.n_compiles = 0
        self.warm_compiles: Optional[int] = None
        self.recent: deque = deque(maxlen=window)
        self.signatures: set = set()

    @property
    def post_warm(self) -> int:
        if self.warm_compiles is None:
            return 0
        return self.n_compiles - self.warm_compiles


class DispatchSentinel:
    """Wrap jitted callables; count, budget, and storm-check compiles."""

    def __init__(self, *, storm_window: int = STORM_WINDOW,
                 storm_threshold: int = STORM_THRESHOLD):
        self.storm_window = storm_window
        self.storm_threshold = storm_threshold
        self.stats: Dict[str, CallableStats] = {}

    def wrap(self, name: str, fn: Callable, *,
             storm_guard: bool = True) -> Callable:
        """Return ``fn`` wrapped with compile counting under ``name``.

        ``storm_guard=False`` for callables with legitimate per-workload
        shape diversity (prefill/commit batches): counted, never raised
        on mid-run density — post-warmup budgeting still applies.
        """
        st = self.stats[name] = CallableStats(name, storm_guard,
                                              self.storm_window)
        probe = getattr(fn, "_cache_size", None)

        def sentineled(*args, **kwargs):
            st.n_calls += 1
            if callable(probe):
                before = probe()
                result = fn(*args, **kwargs)
                compiled = probe() > before
            else:
                sig = _signature((args, kwargs))
                compiled = sig not in st.signatures
                st.signatures.add(sig)
                result = fn(*args, **kwargs)
            if compiled:
                st.n_compiles += 1
            st.recent.append(compiled)
            if st.storm_guard and st.n_calls >= self.storm_window:
                dense = sum(st.recent)
                if dense >= self.storm_threshold:
                    raise InvariantViolation(
                        "jit_dispatch",
                        f"recompile storm on '{name}': {dense} compiles in "
                        f"the last {len(st.recent)} calls "
                        f"({st.n_compiles} total over {st.n_calls} calls) — "
                        "a Python-level static arg or shape is varying per "
                        "call, so every dispatch pays an XLA compile",
                        state={"dispatch": self.report()})
            return result

        sentineled.__wrapped__ = fn
        sentineled.__name__ = name
        return sentineled

    # --- warmup budgeting ----------------------------------------------------
    def mark_warm(self) -> None:
        """Snapshot compile counts: everything so far was warmup."""
        for st in self.stats.values():
            st.warm_compiles = st.n_compiles

    def post_warm_compiles(self) -> Dict[str, int]:
        """Per-callable compiles since :meth:`mark_warm` (0 before it)."""
        return {name: st.post_warm for name, st in self.stats.items()}

    def check(self, budget: int = 0) -> None:
        """Raise when any callable recompiled more than ``budget`` times
        after :meth:`mark_warm` — the compiled-once guarantee."""
        over = {name: n for name, n in self.post_warm_compiles().items()
                if n > budget}
        if over:
            raise InvariantViolation(
                "jit_dispatch",
                f"post-warmup recompiles exceed budget {budget}: {over} — "
                "the hot path is no longer compiled-once",
                state={"dispatch": self.report()})

    # --- reporting -----------------------------------------------------------
    @property
    def total_compiles(self) -> int:
        return sum(st.n_compiles for st in self.stats.values())

    def report(self) -> Dict[str, Dict[str, int]]:
        return {name: {"calls": st.n_calls, "compiles": st.n_compiles,
                       "post_warm": st.post_warm}
                for name, st in self.stats.items()}
