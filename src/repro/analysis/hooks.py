"""Call-site invariant hooks: the ``sanitize_level="call"`` tier.

The step-boundary sanitizer (``invariants.KVSanitizer``) tells you a
step corrupted KV state; it cannot tell you *which call* did it — a
single splitwiser step can admit, reclaim, COW, share and free dozens of
pages.  This module wraps every mutating entry point on
:class:`~repro.core.kv_cache.PageAllocator` and
:class:`~repro.core.prefix_cache.PrefixCache` so the relevant invariant
subset runs immediately at the mutator's exit, and a violation is raised
attributed to the exact call site: method name, argument digest, request
id, and the scheduler event tail.

Per-mutator subsets (keys of ``invariants.CHECKS``): each hook runs only
the invariants that call can break, so the call tier stays affordable —
``alloc`` cannot corrupt trie structure, ``insert`` cannot double-free.

Reentrancy: the public mutators nest (``cow_partial`` calls ``share``
and ``prepare_write``; ``alloc`` drains ``pop_reclaimable`` through
``_pop_free``), and *mid*-compound state is legitimately inconsistent —
e.g. while ``alloc`` is popping its second page, the first sits in no
bucket.  A depth guard therefore runs checks only at the exit of the
outermost hooked call, which is also the call site a human wants the
violation attributed to.  Directly-invoked ``pop_reclaimable`` is the
one mutator whose *exit* state is legitimately non-conserving — the
returned page is in the caller's hands, in no bucket — so its check
exempts exactly that page.

Engine-free by design: ``install_call_hooks(alloc, cache)`` works on a
bare allocator/cache pair (the hypothesis property suite installs it on
its random-lifecycle machine); the engine's ``KVSanitizer`` passes a
``context_fn`` so violations carry engine state and sched events.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.analysis.invariants import verify_subset

# method -> invariant-subset run at its exit (keys of invariants.CHECKS)
ALLOCATOR_HOOKS: Dict[str, Tuple[str, ...]] = {
    "alloc": ("page_conservation", "refcount_honesty"),
    "free": ("page_conservation", "refcount_honesty", "trie_structure"),
    "share": ("page_conservation", "refcount_honesty", "cow_exclusivity"),
    "prepare_write": ("page_conservation", "refcount_honesty",
                      "cow_exclusivity"),
    "cow_partial": ("page_conservation", "refcount_honesty",
                    "cow_exclusivity", "trie_structure"),
}
CACHE_HOOKS: Dict[str, Tuple[str, ...]] = {
    "insert": ("trie_structure", "cow_exclusivity"),
    "pop_reclaimable": ("page_conservation", "trie_structure"),
    "_pop_blocked": ("trie_structure",),
}

# mutators whose first positional argument is a request id
_RID_FIRST = frozenset(
    {"alloc", "free", "share", "prepare_write", "cow_partial"})

_ARGS_DIGEST_CAP = 96


def _digest(args: tuple, kwargs: dict) -> str:
    """Human-readable argument digest, hash-suffixed when truncated."""
    text = ", ".join([repr(a) for a in args]
                     + [f"{k}={v!r}" for k, v in kwargs.items()])
    if len(text) > _ARGS_DIGEST_CAP:
        tag = hashlib.blake2s(text.encode()).hexdigest()[:8]
        text = f"{text[:_ARGS_DIGEST_CAP]}...#{tag}"
    return text


class CallHooks:
    """Installed hook set; hold on to it for counters and uninstall.

    Attributes
        n_call_checks   invariant-subset validations run at call sites
        calls           per-method invocation counts
    """

    def __init__(self, alloc, cache, *,
                 context_fn: Optional[Callable[[], Tuple[Optional[dict],
                                                         Optional[list]]]] = None):
        self.alloc = alloc
        self.cache = cache
        self.context_fn = context_fn
        self.n_call_checks = 0
        self.calls: Dict[str, int] = {}
        self._depth = 0
        self._wrapped: List[Tuple[Any, str]] = []
        for name, checks in ALLOCATOR_HOOKS.items():
            self._wrap(alloc, name, checks)
        if cache is not None:
            for name, checks in CACHE_HOOKS.items():
                self._wrap(cache, name, checks)

    # --- installation ------------------------------------------------------
    def _wrap(self, obj, name: str, checks: Tuple[str, ...]) -> None:
        orig = getattr(obj, name)

        def hooked(*args, __orig=orig, __name=name, __checks=checks, **kwargs):
            self._depth += 1
            try:
                result = __orig(*args, **kwargs)
            finally:
                self._depth -= 1
            if self._depth == 0:
                self._check(__name, __checks, args, kwargs, result)
            return result

        hooked.__wrapped__ = orig
        hooked.__name__ = name
        setattr(obj, name, hooked)      # instance attr shadows the class method
        self._wrapped.append((obj, name))

    def uninstall(self) -> None:
        """Restore the original (class-level) methods."""
        for obj, name in self._wrapped:
            if name in vars(obj):
                delattr(obj, name)
        self._wrapped.clear()

    # --- checking ----------------------------------------------------------
    def _check(self, name: str, checks: Tuple[str, ...],
               args: tuple, kwargs: dict, result) -> None:
        self.n_call_checks += 1
        self.calls[name] = self.calls.get(name, 0) + 1
        exempt = frozenset()
        if name == "pop_reclaimable" and isinstance(result, int):
            exempt = frozenset((result,))
        extra, events = (None, None)
        if self.context_fn is not None:
            extra, events = self.context_fn()
        call_site = {
            "method": name,
            "args": _digest(args, kwargs),
            "rid": (args[0] if name in _RID_FIRST and args else None),
            "n_call": self.calls[name],
        }
        verify_subset(self.alloc, self.cache, checks, exempt=exempt,
                      extra=extra, events=events, call_site=call_site)


def install_call_hooks(alloc, cache=None, *,
                       context_fn: Optional[Callable[[], Tuple[Optional[dict],
                                                               Optional[list]]]] = None
                       ) -> CallHooks:
    """Wrap the mutating entry points of ``alloc`` (and ``cache``,
    defaulting to ``alloc.cache``) with exit-time invariant checks.
    Returns the :class:`CallHooks` handle (counters + ``uninstall()``).
    """
    if cache is None:
        cache = alloc.cache
    return CallHooks(alloc, cache, context_fn=context_fn)
