"""Runtime KV-state sanitizer: the serving stack's cross-module contract
as machine-checked invariants.

The serving core is a web of state shared across modules — refcounted
copy-on-write pages (``core/kv_cache.py``), a token-granular prefix trie
with partial leaves (``core/prefix_cache.py``), and policy-driven
reclaim/preemption (``core/scheduler.py``).  Each module documents its
side of the contract; this module makes the *whole* contract executable,
so a violation fails loudly at the step that corrupts state instead of
surfacing N steps later as a wrong token or a phantom OutOfPages.

Gating (``ServeConfig.sanitize_level``)
    ``off``     never check (production default; zero overhead).
    ``finish``  run the full check after any engine step that finished a
                request — terminal points are where insert/free/requeue
                interact, which is where past bugs clustered.
    ``step``    run the full check after *every* engine step (CI mode;
                tier-1 and the hypothesis suite run under this level).
    ``call``    everything ``step`` does, plus call-site hooks
                (``analysis/hooks.py``) around every mutating
                ``PageAllocator``/``PrefixCache`` entry point: the
                relevant invariant subset runs at the mutator's exit, so
                a violation is attributed to the exact call (method,
                args digest, request id, event tail) instead of
                "somewhere before the step boundary".

At any level above ``off`` the sanitizer also runs the **differential
preempt/resume checker**: at preemption it snapshots the victim's
committed cached pages that other live requests keep referenced — the
exact pages ``Engine.resume_safe_pages`` promises survive the eviction —
and at the victim's re-admission verifies the resume remapped every
promised page that is still cached (page ids, ownership, refcounts).  A
promise may lapse only by eviction: if the page left the trie under
pressure, recomputing it is legitimate; if it is still cached and the
resume recomputed it anyway, prefix matching regressed and the checker
fails loudly.

Invariants checked
    * **page conservation** — the free list, the cache's reclaimable
      pool, and live-referenced pages partition the usable pool exactly
      (no page lost, none counted twice, the trash page in none of them);
    * **refcount honesty** — allocator refcounts equal the multiset of
      per-request page-table references; zero-ref entries leave the
      table entirely;
    * **COW exclusivity** — a page mapped by more than one request is
      registered in the prefix trie (sharing only arises through the
      cache; ``prepare_write`` can only guard pages it knows are
      shared), or was explicitly orphaned by the blocked-subtree
      eviction fallback; no request maps the same page twice;
    * **trie structure** — parent-before-child, gap-free chains with
      consistent child links, ``1 <= n_valid <= page_size``, partial
      leaves terminal, descendant counts exact, reclaimable pool
      consistent with refcounts (a zero-ref cached page is reclaimable,
      a referenced one is not, none sit on the free list);
    * **scale-sidecar honesty** (``ServeConfig.kv_dtype="int8"``) — the
      engine's :class:`~repro.core.kv_cache.KVQuantSidecar` mirror holds
      exactly one scale entry for every page with live quantized
      contents: every committed-coverage page of every active sequence
      and every cached trie page is registered, no entry survives a
      page's return to the free list (or names the trash page), and the
      device pool's bytes (codes + scale sidecars, K and V, all layers)
      conserve against the allocator's byte-denominated sizing;
    * **scheduler budget honesty** — the pages an admission charged
      against the watermark budget bound what the request actually
      consumed from the free pool through the end of its prefill
      (fresh allocations + reclaimable revivals + COW copies).  In
      ``mode="chunked"`` admission charges only the cached prefix plus
      one chunk and the budget grows per scheduled chunk
      (:meth:`KVSanitizer.note_chunk`), each growth a pre-commitment
      computed before the chunk allocates;
    * **chunk-plan packing** (``mode="chunked"``) — every round's
      :class:`~repro.core.planner.ChunkPlan` packs all runnable decode
      tokens, never carves a stream past its remaining prefill or the
      budget the decodes leave, and is work-conserving
      (:func:`~repro.core.planner.validate_plan`);
    * **tenant-quota honesty** (``admission_policy="deadline"`` with
      quota'd ``ServeConfig.tenants``) — every quota'd tenant's active
      in-flight footprint (prompt + full generation grant per request,
      ``core/slo.py``) stays within its ``quota_tokens``, except for
      the documented single-oversized-request progress case.

On failure a structured :class:`InvariantViolation` is raised carrying
the violated invariant's name, an allocator/trie/scheduler state dump,
and the tail of the scheduler's :class:`~repro.core.metrics.EventRing`
for post-mortem.

``verify_state(alloc, cache)`` runs the allocator/trie subset without an
engine — the hypothesis property suite drives random lifecycle
interleavings through it.

Adding an invariant: see EXPERIMENTS.md ("adding a lint rule / adding an
invariant").
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

SANITIZE_LEVELS = ("off", "finish", "step", "call")

_EVENT_TAIL = 16      # sched events carried in the violation report
_NODE_DUMP_CAP = 64   # trie nodes listed in the state dump


class InvariantViolation(RuntimeError):
    """A cross-module serving invariant failed.

    Attributes
        invariant   machine-readable name of the violated invariant
                    (e.g. ``"page_conservation"``, ``"refcount_honesty"``)
        state       allocator/trie/scheduler state dump at failure time
        events      tail of the scheduler event ring (post-mortem trace)
        call_site   at ``sanitize_level="call"``: the mutating call the
                    violation was detected at — ``method``, ``args``
                    (digest), ``rid`` (when the first argument is one),
                    ``n_call`` (how many times the method ran)
    """

    def __init__(self, invariant: str, message: str,
                 state: Optional[Dict[str, Any]] = None,
                 events: Optional[List[dict]] = None,
                 call_site: Optional[Dict[str, Any]] = None):
        self.invariant = invariant
        self.state = state or {}
        self.events = list(events or [])
        self.call_site = call_site or {}
        text = f"[{invariant}] {message}"
        if self.call_site:
            rid = self.call_site.get("rid")
            text += (f"\n--- call site ---\n  "
                     f"{self.call_site.get('method')}"
                     f"({self.call_site.get('args', '')})"
                     + ("" if rid is None else f"  [rid={rid}]")
                     + (f"  (call #{self.call_site['n_call']})"
                        if "n_call" in self.call_site else ""))
        if self.state:
            text += "\n--- state dump ---\n" + json.dumps(
                self.state, indent=1, default=str, sort_keys=True)
        if self.events:
            text += (f"\n--- last {len(self.events)} sched events ---\n"
                     + "\n".join(f"  {e}" for e in self.events))
        super().__init__(text)


# --------------------------------------------------------- state dumps ----
def allocator_state(alloc) -> Dict[str, Any]:
    """JSON-serializable snapshot of a :class:`PageAllocator`."""
    return {
        "n_pages": alloc.n_pages,
        "page_size": alloc.page_size,
        "n_free": alloc.n_free,
        "free_list": sorted(alloc._free),
        "refs": {str(p): c for p, c in sorted(alloc._ref.items())},
        "owned": {str(r): list(pages) for r, pages in sorted(alloc._owned.items())},
        "consumed": {str(r): c for r, c in sorted(alloc._consumed.items())},
    }


def trie_state(cache) -> Dict[str, Any]:
    """JSON-serializable snapshot of a :class:`PrefixCache`."""
    if cache is None:
        return {"enabled": False}
    nodes = {}
    for node in list(cache._nodes.values())[:_NODE_DUMP_CAP]:
        nodes[str(node.nid)] = {
            "page": node.page,
            "parent": None if node.parent is None else node.parent.nid,
            "n_valid": node.n_valid,
            "depth": node.depth,
            "n_desc": node.n_desc,
            "reclaimable": node.reclaimable,
        }
    return {
        "enabled": True,
        "n_nodes": len(cache._nodes),
        "n_reclaimable": cache.n_reclaimable,
        "reclaimable_pages": sorted(cache._reclaimable),
        "orphaned_shared": sorted(cache.orphaned_shared),
        "nodes": nodes,
        "nodes_truncated": len(cache._nodes) > _NODE_DUMP_CAP,
    }


def _state(alloc, cache, extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    state = {"allocator": allocator_state(alloc), "trie": trie_state(cache)}
    if extra:
        state.update(extra)
    return state


# ------------------------------------------------------------- checkers ----
def _check_page_conservation(fail, alloc, cache,
                             exempt: frozenset = frozenset()) -> None:
    free_list = list(alloc._free)
    free = set(free_list)
    if len(free) != len(free_list):
        dupes = sorted(p for p in free if free_list.count(p) > 1)
        fail("page_conservation",
             f"free list holds duplicate entries {dupes} (double free)")
    live = set(alloc._ref)
    recl = set(cache._reclaimable) if cache is not None else set()
    for name, pages in (("free list", free), ("live set", live),
                        ("reclaimable pool", recl)):
        if alloc.trash_page in pages:
            fail("page_conservation",
                 f"trash page {alloc.trash_page} appears in the {name}")
    overlaps = [("free/live", free & live), ("free/reclaimable", free & recl),
                ("live/reclaimable", live & recl)]
    for name, inter in overlaps:
        if inter:
            fail("page_conservation",
                 f"page sets overlap ({name}): {sorted(inter)}")
    usable = alloc.n_pages - 1
    # ``exempt``: pages legitimately in transit at a call-site check —
    # e.g. the page ``pop_reclaimable`` just returned sits in the
    # caller's hands, in no bucket, until the caller re-registers it.
    in_transit = {p for p in exempt
                  if p not in free and p not in live and p not in recl}
    total = len(free) + len(live) + len(recl) + len(in_transit)
    if total != usable:
        missing = set(range(usable)) - free - live - recl - in_transit
        fail("page_conservation",
             f"free({len(free)}) + live({len(live)}) + "
             f"reclaimable({len(recl)}) = {total - len(in_transit)} != "
             f"pool size {usable}"
             + (f" (exempt in-transit: {sorted(in_transit)})" if in_transit else "")
             + (f"; leaked pages {sorted(missing)}" if missing else ""))
    if alloc.n_free != len(free) + len(recl):
        fail("page_conservation",
             f"n_free property reports {alloc.n_free}, actual "
             f"free+reclaimable is {len(free) + len(recl)}")


def _check_refcount_honesty(fail, alloc, cache=None) -> None:
    del cache  # uniform checker signature; refcounts are allocator-local
    for page, refs in alloc._ref.items():
        if refs < 1:
            fail("refcount_honesty",
                 f"page {page} has refcount {refs}; zero-ref entries must "
                 "leave the table (park reclaimable or return to the free list)")
    counts: Dict[int, int] = {}
    for pages in alloc._owned.values():
        for p in pages:
            counts[p] = counts.get(p, 0) + 1
    if counts != alloc._ref:
        drift = {p: (counts.get(p, 0), alloc._ref.get(p, 0))
                 for p in set(counts) | set(alloc._ref)
                 if counts.get(p, 0) != alloc._ref.get(p, 0)}
        fail("refcount_honesty",
             "allocator refcounts disagree with per-request page tables "
             f"(page: (table refs, refcount)): {drift}")


def _check_cow_exclusivity(fail, alloc, cache) -> None:
    for rid, pages in alloc._owned.items():
        if len(set(pages)) != len(pages):
            dupes = sorted(p for p in set(pages) if pages.count(p) > 1)
            fail("cow_exclusivity",
                 f"request {rid} maps pages {dupes} more than once")
    for page, refs in alloc._ref.items():
        if refs <= 1:
            continue
        cached = cache is not None and cache.is_cached(page)
        orphaned = cache is not None and page in cache.orphaned_shared
        if not (cached or orphaned):
            fail("cow_exclusivity",
                 f"page {page} is mapped by {refs} requests but is not "
                 "registered in the prefix trie: sharing outside the cache "
                 "contract means copy-on-write cannot protect its readers")


def _check_trie_structure(fail, alloc, cache) -> None:
    if cache is None:
        return
    if len(cache._by_page) != len(cache._nodes):
        fail("trie_structure",
             f"page index holds {len(cache._by_page)} entries for "
             f"{len(cache._nodes)} nodes (aliased or leaked pages)")
    n_children: Dict[int, int] = {}
    n_desc: Dict[int, int] = {}
    for node in cache._nodes.values():
        if cache._by_page.get(node.page) is not node:
            fail("trie_structure",
                 f"node {node.nid} (page {node.page}) missing from or "
                 "aliased in the page index")
        if not 1 <= node.n_valid <= cache.page_size:
            fail("trie_structure",
                 f"node {node.nid} has n_valid={node.n_valid} outside "
                 f"[1, page_size={cache.page_size}]")
        if node.n_valid < cache.page_size and node.children:
            fail("trie_structure",
                 f"partial leaf {node.nid} (n_valid={node.n_valid}) has "
                 f"{len(node.children)} children; partial pages are "
                 "terminal — nothing can chain past an unwritten tail")
        if node.parent is None:
            if node.depth != 0:
                fail("trie_structure",
                     f"root-level node {node.nid} has depth {node.depth}")
            if cache._roots.get(node.key[1]) is not node:
                fail("trie_structure",
                     f"root-level node {node.nid} is not linked from the "
                     "root map (orphaned chain head)")
        else:
            parent = node.parent
            if cache._nodes.get(parent.key) is not parent:
                fail("trie_structure",
                     f"node {node.nid} (page {node.page}) points at parent "
                     f"{parent.nid} which is not in the trie (orphaned "
                     "node: its chain has a gap)")
            if parent.nid >= node.nid:
                fail("trie_structure",
                     f"node {node.nid} was created before its parent "
                     f"{parent.nid} (parent-before-child violated)")
            if node.depth != parent.depth + 1:
                fail("trie_structure",
                     f"node {node.nid} depth {node.depth} != parent depth "
                     f"{parent.depth} + 1")
            if parent.children.get(node.key[1]) is not node:
                fail("trie_structure",
                     f"node {node.nid} is not linked from its parent's "
                     "children (gap in the chain)")
            anc = parent
            while anc is not None:
                n_desc[anc.nid] = n_desc.get(anc.nid, 0) + 1
                anc = anc.parent
            n_children[parent.nid] = n_children.get(parent.nid, 0) + 1
    for node in cache._nodes.values():
        if node.n_desc != n_desc.get(node.nid, 0):
            fail("trie_structure",
                 f"node {node.nid} records n_desc={node.n_desc}, actual "
                 f"descendant count is {n_desc.get(node.nid, 0)}")
        if len(node.children) != n_children.get(node.nid, 0):
            fail("trie_structure",
                 f"node {node.nid} child links ({len(node.children)}) "
                 f"disagree with the node table ({n_children.get(node.nid, 0)})")
        for chunk, child in node.children.items():
            if child.parent is not node or child.key != (node.nid, chunk):
                fail("trie_structure",
                     f"child link {node.nid} -> {child.nid} is inconsistent "
                     "with the child's own key/parent")
    for chunk, node in cache._roots.items():
        if cache._nodes.get(node.key) is not node or node.key != (0, chunk):
            fail("trie_structure",
                 f"root link {chunk!r} points at a dead or mis-keyed node")
    # reclaimable pool vs refcounts
    for page, node in cache._reclaimable.items():
        if cache._by_page.get(page) is not node:
            fail("trie_structure",
                 f"reclaimable page {page} is not (or no longer) cached")
        if not node.reclaimable:
            fail("trie_structure",
                 f"reclaimable page {page} has reclaimable=False on its node")
        if page in alloc._ref:
            fail("trie_structure",
                 f"page {page} is reclaimable while still referenced "
                 f"({alloc._ref[page]} refs): it could be stripped out from "
                 "under a live request")
    free = set(alloc._free)
    for page, node in cache._by_page.items():
        if page not in alloc._ref and page not in cache._reclaimable:
            fail("trie_structure",
                 f"cached page {page} has zero refs but is not parked "
                 "reclaimable (leaked capacity)")
        if node.reclaimable and page not in cache._reclaimable:
            fail("trie_structure",
                 f"node for page {page} is flagged reclaimable but absent "
                 "from the reclaimable pool")
        if page in free:
            fail("trie_structure",
                 f"cached page {page} sits on the free list: the trie "
                 "would serve stale KV after it is reallocated")


# Named registry: call-site hooks (``analysis/hooks.py``) run per-mutator
# subsets of these by name; ``verify_state`` runs them all.
CHECKS = {
    "page_conservation": _check_page_conservation,
    "refcount_honesty": _check_refcount_honesty,
    "cow_exclusivity": _check_cow_exclusivity,
    "trie_structure": _check_trie_structure,
}

_STATE_CHECKS = tuple(CHECKS.values())


def verify_subset(alloc, cache, names,
                  exempt: frozenset = frozenset(),
                  extra: Optional[Dict[str, Any]] = None,
                  events: Optional[List[dict]] = None,
                  call_site: Optional[Dict[str, Any]] = None) -> None:
    """Run the named subset of the state checks (``CHECKS`` keys); raise
    :class:`InvariantViolation` on the first failure, tagged with
    ``call_site`` when the caller is a call-tier hook.

    ``exempt`` pages are excused from page-conservation bucket membership
    (in transit between owners at the instrumented call's exit).
    """
    def fail(invariant: str, message: str) -> None:
        raise InvariantViolation(invariant, message,
                                 state=_state(alloc, cache, extra),
                                 events=events, call_site=call_site)

    for name in names:
        check = CHECKS[name]
        if name == "page_conservation":
            check(fail, alloc, cache, exempt=exempt)
        else:
            check(fail, alloc, cache)


def verify_state(alloc, cache=None,
                 extra: Optional[Dict[str, Any]] = None,
                 events: Optional[List[dict]] = None) -> None:
    """Run every allocator/trie invariant; raise :class:`InvariantViolation`
    on the first failure.  ``cache`` defaults to ``alloc.cache``.

    Engine-free entry point: the hypothesis property suite calls this
    after every random lifecycle op; :class:`KVSanitizer` wraps it with
    engine/scheduler context.
    """
    if cache is None:
        cache = alloc.cache
    verify_subset(alloc, cache, CHECKS, extra=extra, events=events)


# ------------------------------------------------------------ sanitizer ----
class KVSanitizer:
    """Engine-attached runtime sanitizer (``ServeConfig.sanitize_level``).

    The engine calls :meth:`after_step` at the end of every ``step()``;
    the scheduler reports each admission's charged page budget
    (:meth:`note_admit`) and the engine reports prefill completion
    (:meth:`note_first_token`), closing the loop on scheduler budget
    honesty.  All checks are read-only: token streams are bit-identical
    across sanitize levels.
    """

    def __init__(self, engine):
        self.eng = engine
        self.level = engine.serve.sanitize_level
        if self.level not in SANITIZE_LEVELS:     # engine built around config
            raise ValueError(f"unknown sanitize_level {self.level!r}; "
                             f"supported: {', '.join(SANITIZE_LEVELS)}")
        # rid -> (pages charged at admission, progress-override flag)
        self._budgets: Dict[int, Tuple[int, bool]] = {}
        # rid -> promise snapshot taken at preemption (differential checker)
        self._preempt_snaps: Dict[int, Dict[str, Any]] = {}
        self.n_checks = 0     # full-state validations performed (overhead/bench)
        self.call_hooks = None
        if self.level == "call":
            from repro.analysis.hooks import install_call_hooks  # lazy: avoid cycle
            self.call_hooks = install_call_hooks(
                engine.alloc, engine.prefix_cache,
                context_fn=lambda: (self._engine_state(), self._events_tail()))

    @property
    def n_call_checks(self) -> int:
        """Invariant-subset checks run by call-site hooks (0 below ``call``)."""
        return 0 if self.call_hooks is None else self.call_hooks.n_call_checks

    # --- scheduler hooks ---------------------------------------------------
    def note_admit(self, rid: int, pages: int, override: bool) -> None:
        """An admission round charged ``pages`` against the watermark
        budget for ``rid`` (``override``: the bare-fit progress override
        fired, so the charge deliberately ignores headroom and transient
        COW capacity — exempt from the budget check)."""
        self._budgets[rid] = (pages, override)

    def note_chunk(self, rid: int, pages: int) -> None:
        """Chunked mode scheduled another planner chunk of ``rid``'s
        prefill, pre-committing ``pages`` more from the free pool
        (admission charged only the cached prefix plus one chunk; the
        budget grows chunk by chunk as the planner schedules the rest,
        and :meth:`note_first_token` still bounds what the whole prefill
        actually consumed)."""
        if rid in self._budgets:
            need, override = self._budgets[rid]
            self._budgets[rid] = (need + pages, override)

    def note_plan(self, plan, remaining, n_decode_tokens: int) -> None:
        """Chunked mode produced ``plan`` for a round with per-stream
        ``remaining`` prefill tokens and ``n_decode_tokens`` runnable
        decodes; fail loudly if it breaks the packing contract (prefill
        over the budget decodes leave, a stream carved past its
        remainder, decodes dropped, or budget wasted while work
        remains)."""
        # lazy: keep the analysis layer importable without core modules
        from repro.core.planner import validate_plan
        try:
            validate_plan(plan, remaining, n_decode_tokens)
        except ValueError as e:
            self._fail("chunk_plan", str(e))

    def note_preempt(self, req, committed: int) -> None:
        """``req`` is being preempted with ``committed`` tokens of useful
        work; its next admission re-budgets from scratch.

        Called by the scheduler *after* ``cache_insert`` registered the
        victim's committed pages but *before* ``alloc.free`` drops its
        references — the exact instant ``resume_safe_pages`` prices.  We
        snapshot the promise: the committed full-page chain, restricted
        to pages some *other* live request keeps referenced once the
        victim's own references are gone (those survive eviction; the
        rest park reclaimable and may be stripped under pressure).
        :meth:`note_resume` settles the promise at re-admission.
        """
        self._budgets.pop(req.rid, None)
        cache = self.eng.prefix_cache
        if cache is None:
            return
        alloc = self.eng.alloc
        toks = (req.prompt + req.out_tokens)[:committed]
        chain = cache.match(toks)
        owned = set(alloc.owned(req.rid))
        promised = [p for p in chain
                    if alloc.ref_count(p) >= (2 if p in owned else 1)]
        if promised:
            self._preempt_snaps[req.rid] = {
                "committed": committed,
                "chain": list(chain),
                "promised": promised,
                # refs surviving after the victim's own free
                "refs": {p: alloc.ref_count(p) - (1 if p in owned else 0)
                         for p in promised},
                "step": self.eng.metrics.n_steps,
            }

    def note_resume(self, req, mapped_pages: List[int]) -> None:
        """A previously-preempted ``req`` was re-admitted and its prefix
        re-matched ``mapped_pages``.  Settle the preemption promise:
        every promised page still in the trie must have been remapped
        (same page id, now owned by the request, still referenced).
        Pages evicted since the preempt are excused — leaf-first reclaim
        and whole-subtree blocked eviction keep chains gap-free, so a
        still-cached promised page is always reachable by the matcher.
        """
        snap = self._preempt_snaps.pop(req.rid, None)
        if snap is None:
            return
        cache = self.eng.prefix_cache
        alloc = self.eng.alloc
        mapped = set(mapped_pages)
        owned = set(alloc.owned(req.rid))
        for p in snap["promised"]:
            if cache is None or not cache.is_cached(p):
                continue          # evicted under pressure: promise lapsed
            if p not in mapped:
                self._fail(
                    "preempt_resume",
                    f"resume of request {req.rid} recomputed promised page "
                    f"{p}: at preemption (step {snap['step']}, "
                    f"{snap['committed']} committed tokens) it survived with "
                    f"{snap['refs'][p]} external reference(s) and it is "
                    f"still cached now, but the resume's prefix match "
                    f"returned {sorted(mapped)} — resume_safe_pages promised "
                    "a remap that prefix matching failed to deliver")
            if p not in owned:
                self._fail(
                    "preempt_resume",
                    f"resume of request {req.rid} matched promised page {p} "
                    "but the request does not own it — the remap never "
                    "acquired a reference")
            if alloc.ref_count(p) < 1:
                self._fail(
                    "preempt_resume",
                    f"promised page {p} remapped by request {req.rid} has "
                    f"refcount {alloc.ref_count(p)}")

    # --- engine hooks ------------------------------------------------------
    def note_first_token(self, rid: int) -> None:
        """Prefill complete: everything the request took from the free
        pool since admission (fresh allocations, reclaimable revivals,
        COW copies) must fit the pages its admission charged."""
        budget = self._budgets.pop(rid, None)
        if budget is None:
            return
        need, override = budget
        if override:
            return
        consumed = self.eng.alloc.consumed(rid)
        if consumed > need:
            self._fail("scheduler_budget",
                       f"request {rid} consumed {consumed} pages from the "
                       f"free pool during its prefill but admission charged "
                       f"only {need}: the watermark budget under-reserved "
                       "(misses, reclaimable revivals, or COW copies were "
                       "not counted)")

    def after_step(self, finished: bool) -> None:
        """End-of-step gate: full validation at ``step``/``call`` levels
        always, at ``finish`` level only when this step finished a
        request.  (``call`` additionally checks inside the step, at each
        mutating allocator/cache call — see ``analysis/hooks.py``.)"""
        if self.level in ("step", "call") or (self.level == "finish" and finished):
            self.check_now()

    # --- validation --------------------------------------------------------
    def _events_tail(self) -> List[dict]:
        return list(self.eng.metrics.sched_events[-_EVENT_TAIL:])

    def _engine_state(self) -> Dict[str, Any]:
        eng = self.eng
        return {"engine": {
            "mode": eng.serve.mode,
            "step": eng.metrics.n_steps,
            "slots": {str(i): {"rid": s.req.rid, "seq_len": s.seq_len}
                      for i, s in enumerate(eng.slots) if s is not None},
            "streams": {str(i): {"rid": s.req.rid, "pos": s.pos,
                                 "len": len(s.tokens)}
                        for i, s in enumerate(eng.streams) if s is not None},
            "waiting": [r.rid for r in eng.sched.waiting],
            "budgets": {str(r): list(b) for r, b in self._budgets.items()},
        }}

    def _fail(self, invariant: str, message: str) -> None:
        raise InvariantViolation(
            invariant, message,
            state=_state(self.eng.alloc, self.eng.prefix_cache,
                         self._engine_state()),
            events=self._events_tail())

    def _check_scale_sidecar(self) -> None:
        """``kv_dtype="int8"``: the quant sidecar mirror is honest."""
        eng = self.eng
        quant = eng.kv_quant
        alloc = eng.alloc
        cache = eng.prefix_cache
        free = set(alloc._free)
        for page, count in quant.entries.items():
            if count != 1:
                self._fail("scale_sidecar",
                           f"page {page} holds {count} scale entries; a "
                           "quantized page carries exactly one per "
                           "(token, head) plane")
            if page == alloc.trash_page:
                self._fail("scale_sidecar",
                           f"trash page {page} holds a scale entry; inactive "
                           "rows scatter garbage there and nothing may read "
                           "it back as valid quantized KV")
            if page in free:
                self._fail("scale_sidecar",
                           f"page {page} sits on the free list but still "
                           "holds a scale entry: the next owner would "
                           "dequantize with a stale scale")
            if page not in alloc._ref and \
                    (cache is None or not cache.is_cached(page)):
                self._fail("scale_sidecar",
                           f"scale entry leaked: page {page} is neither "
                           "live-referenced nor cached")
        for kind, cont in (("slot", eng.slots), ("stream", eng.streams)):
            for i, s in enumerate(cont):
                if s is None:
                    continue
                committed = s.seq_len if kind == "slot" else s.pos
                owned = alloc.owned(s.req.rid)
                for p in owned[: alloc.pages_needed(committed)]:
                    if p not in quant.entries:
                        self._fail(
                            "scale_sidecar",
                            f"{kind}[{i}] (rid {s.req.rid}) committed page "
                            f"{p} has no scale entry: its int8 codes cannot "
                            "be dequantized")
        if cache is not None:
            for page in cache._by_page:
                if page not in quant.entries:
                    self._fail("scale_sidecar",
                               f"cached trie page {page} has no scale entry: "
                               "a future hit would remap undequantizable KV")
        # byte conservation: device pool == allocator sizing == metrics
        import jax  # lazy: keep the analysis layer importable without jax
        pool = sum(x.nbytes
                   for x in jax.tree.leaves((eng.k_pages, eng.v_pages)))
        expect = alloc.n_pages * alloc.page_bytes
        if pool != expect or eng.metrics.kv_pool_bytes != expect:
            self._fail("scale_sidecar",
                       f"pool bytes do not conserve: device arrays hold "
                       f"{pool}, allocator sizing says {alloc.n_pages} pages "
                       f"x {alloc.page_bytes} B = {expect}, metrics report "
                       f"{eng.metrics.kv_pool_bytes}")

    def check_now(self) -> None:
        """Run the full cross-module contract against live engine state."""
        eng = self.eng
        self.n_checks += 1
        verify_state(eng.alloc, eng.prefix_cache,
                     extra=self._engine_state(), events=self._events_tail())
        if getattr(eng, "kv_quant", None) is not None:
            self._check_scale_sidecar()
        active: Dict[int, str] = {}
        for kind, cont in (("slot", eng.slots), ("stream", eng.streams)):
            for i, s in enumerate(cont):
                if s is None:
                    continue
                rid = s.req.rid
                where = f"{kind}[{i}]"
                if rid in active:
                    self._fail("request_identity",
                               f"request {rid} is active in both "
                               f"{active[rid]} and {where}")
                active[rid] = where
                committed = s.seq_len if kind == "slot" else s.pos
                owned = eng.alloc.owned(rid)
                need = eng.alloc.pages_needed(committed)
                if len(owned) < need:
                    self._fail("page_coverage",
                               f"{where} (rid {rid}) has {committed} "
                               f"committed tokens needing {need} pages but "
                               f"owns only {len(owned)}")
                if len(owned) > eng.serve.max_pages_per_seq:
                    self._fail("page_coverage",
                               f"{where} (rid {rid}) owns {len(owned)} pages, "
                               f"over max_pages_per_seq="
                               f"{eng.serve.max_pages_per_seq}")
                if kind == "slot":
                    row = [int(p) for p in eng.block_tables[i, :len(owned)]]
                    if row != list(owned):
                        self._fail("block_table",
                                   f"slot {i} (rid {rid}) block-table row "
                                   f"{row} diverged from its allocator "
                                   f"page table {list(owned)}")
        seen_waiting = set()
        for r in eng.sched.waiting:
            if r.rid in active:
                self._fail("request_identity",
                           f"request {r.rid} is simultaneously waiting and "
                           f"active in {active[r.rid]}")
            if r.rid in seen_waiting:
                self._fail("request_identity",
                           f"request {r.rid} queued twice")
            seen_waiting.add(r.rid)
        if eng.serve.admission_policy == "deadline" and any(
                t.quota_tokens is not None for t in eng.serve.tenants):
            self._check_tenant_quota()

    def _check_tenant_quota(self) -> None:
        """``admission_policy="deadline"`` + quota'd tiers: admission's
        quota promise holds against live engine state — each quota'd
        tenant's active footprint (prompt + full generation grant per
        request, ``core/slo.py``) stays within ``quota_tokens``, except
        for the documented single-oversized case (one request bigger
        than its tenant's whole quota admits on an idle tenant; the
        ``holds`` progress rule, so quotas bound concurrency without
        wedging a tenant)."""
        from repro.core.slo import request_footprint
        eng = self.eng
        held: Dict[str, list] = {}
        seen = set()
        for cont in (eng.slots, eng.streams):
            for s in cont:
                if s is None or s.req.rid in seen:
                    continue
                seen.add(s.req.rid)
                held.setdefault(eng.effective_slo(s.req).tenant, []).append(
                    request_footprint(s.req))
        for tier in eng.serve.tenants:
            if tier.quota_tokens is None:
                continue
            fps = held.get(tier.name, [])
            if sum(fps) > tier.quota_tokens and len(fps) > 1:
                self._fail(
                    "tenant_quota",
                    f"tenant {tier.name!r} holds {sum(fps)} in-flight "
                    f"footprint tokens across {len(fps)} requests, over its "
                    f"quota of {tier.quota_tokens}: deadline admission must "
                    "queue the burst behind the quota (only a single "
                    "oversized request may exceed it)")
