# Execution-analysis layer for the serving stack: a runtime sanitizer
# (invariants.py) that validates the cross-module allocator/trie/scheduler
# contract after engine steps, call-site hooks (hooks.py) that attribute
# violations to the exact mutating call at sanitize_level="call", a
# cross-mode differential harness (differential.py), a jit-dispatch
# sentinel (dispatch.py) that proves the hot path stays compiled-once,
# and an AST lint (lint.py) encoding repo-specific pitfalls learned from
# real fixed bugs.
#
# This package must stay importable without jax/numpy: the lint runs in
# CI environments (and pre-commit hooks) that never install the heavy
# deps, so keep module-level imports stdlib-only.
from repro.analysis.differential import (diff_fingerprints, run_cross_mode,
                                         state_fingerprint)
from repro.analysis.dispatch import DispatchSentinel
from repro.analysis.hooks import CallHooks, install_call_hooks
from repro.analysis.invariants import (CHECKS, InvariantViolation, KVSanitizer,
                                       SANITIZE_LEVELS, verify_state,
                                       verify_subset)

__all__ = [
    "CHECKS", "CallHooks", "DispatchSentinel", "InvariantViolation",
    "KVSanitizer", "SANITIZE_LEVELS", "diff_fingerprints",
    "install_call_hooks", "run_cross_mode", "state_fingerprint",
    "verify_state", "verify_subset",
]
