# Invariant-analysis layer for the serving stack: a runtime sanitizer
# (invariants.py) that validates the cross-module allocator/trie/scheduler
# contract after engine steps, and an AST lint (lint.py) encoding
# repo-specific pitfalls learned from real fixed bugs.
#
# This package must stay importable without jax/numpy: the lint runs in
# CI environments (and pre-commit hooks) that never install the heavy
# deps, so keep module-level imports stdlib-only.
from repro.analysis.invariants import (InvariantViolation, KVSanitizer,
                                       SANITIZE_LEVELS, verify_state)

__all__ = [
    "InvariantViolation", "KVSanitizer", "SANITIZE_LEVELS", "verify_state",
]
