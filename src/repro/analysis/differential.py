"""Cross-mode differential harness: diff *allocator state*, not just
token streams.

The repo has always proven the three serving modes bit-identical on
token output; that is necessary but not sufficient — two modes can emit
the same tokens while leaving different KV state behind (a leaked page,
a chain inserted at the wrong granularity, a refcount that never
dropped), and the divergence only bites the *next* workload.  This
module fingerprints final allocator+cache state in a canonical,
page-id-independent form and diffs it across modes.

The fingerprint keys trie content by **token path**, not page id or node
id: page numbering depends on allocation order, which legitimately
differs across modes, but the set of cached token chains, their valid
lengths, their reclaimability, and their reference counts must agree on
any workload where scheduling pressure (eviction/preemption order) does
not itself diverge.  Tests assert an empty diff on ample-pool
shared-prefix workloads; under deliberate pressure the harness still
*reports* the drift so a human can judge it.

Stdlib-only: engine/request construction is injected via factories, so
this module never imports jax and stays importable in the lint CI job.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, List, Sequence

_SCALAR_KEYS = ("n_free", "n_reclaimable", "n_live", "n_owned_requests")


def state_fingerprint(alloc, cache=None) -> Dict[str, Any]:
    """Canonical snapshot of allocator+cache state.

    ``chains`` is a sorted list of ``(token_path, n_valid, reclaimable,
    refcount)`` per trie node, where ``token_path`` is the full token
    tuple from the root — identical across runs that cached the same
    content, whatever pages it landed on.
    """
    if cache is None:
        cache = alloc.cache
    chains: List[tuple] = []
    if cache is not None:
        for node in cache._nodes.values():
            parts = []
            n = node
            while n is not None:
                parts.append(n.key[1])
                n = n.parent
            path = tuple(t for chunk in reversed(parts) for t in chunk)
            chains.append((path, node.n_valid, node.reclaimable,
                           alloc.ref_count(node.page)))
    chains.sort()
    return {
        "n_free": len(alloc._free),
        "n_reclaimable": 0 if cache is None else cache.n_reclaimable,
        "n_live": len(alloc._ref),
        "n_owned_requests": sum(1 for pages in alloc._owned.values() if pages),
        "chains": chains,
    }


def diff_fingerprints(a: Dict[str, Any], b: Dict[str, Any], *,
                      label_a: str = "a", label_b: str = "b") -> List[str]:
    """Human-readable differences between two fingerprints ([] if none)."""
    diffs: List[str] = []
    for key in _SCALAR_KEYS:
        if a[key] != b[key]:
            diffs.append(f"{key}: {label_a}={a[key]} {label_b}={b[key]}")
    ca = {c[0]: c[1:] for c in a["chains"]}
    cb = {c[0]: c[1:] for c in b["chains"]}
    for path in sorted(set(ca) | set(cb)):
        tag = f"chain {list(path[:8])}{'...' if len(path) > 8 else ''} (len {len(path)})"
        if path not in cb:
            diffs.append(f"{tag}: cached only in {label_a} {ca[path]}")
        elif path not in ca:
            diffs.append(f"{tag}: cached only in {label_b} {cb[path]}")
        elif ca[path] != cb[path]:
            diffs.append(f"{tag}: (n_valid, reclaimable, refs) "
                         f"{label_a}={ca[path]} {label_b}={cb[path]}")
    return diffs


def run_cross_mode(engine_factory: Callable[[str], Any],
                   requests_factory: Callable[[], Sequence[Any]],
                   modes: Iterable[str] = ("sequential", "splitwiser"),
                   max_steps: int = 100_000) -> Dict[str, Any]:
    """Run the same workload under each mode; diff streams *and* state.

    ``engine_factory(mode)`` builds a fresh engine for the mode;
    ``requests_factory()`` builds a fresh request list per run (requests
    are stateful).  Returns::

        {"modes": [...],
         "streams_match": bool,
         "state_diffs": {mode: [diff lines vs modes[0]]},
         "fingerprints": {mode: fingerprint}}
    """
    modes = list(modes)
    results: Dict[str, Dict[str, Any]] = {}
    for mode in modes:
        eng = engine_factory(mode)
        reqs = list(requests_factory())
        eng.run(reqs, max_steps=max_steps)
        results[mode] = {
            "streams": {r.rid: list(r.out_tokens) for r in reqs},
            "fingerprint": state_fingerprint(eng.alloc, eng.prefix_cache),
        }
    base = modes[0]
    report: Dict[str, Any] = {
        "modes": modes,
        "streams_match": True,
        "state_diffs": {},
        "fingerprints": {m: results[m]["fingerprint"] for m in modes},
    }
    for mode in modes[1:]:
        if results[mode]["streams"] != results[base]["streams"]:
            report["streams_match"] = False
        report["state_diffs"][mode] = diff_fingerprints(
            results[base]["fingerprint"], results[mode]["fingerprint"],
            label_a=base, label_b=mode)
    return report
