"""Repo-specific AST lint: static rejection of bug classes this repo has
already shipped and fixed.

Generic linters (ruff) catch generic mistakes; each rule here encodes a
*specific* incident or contract from this codebase's history:

RPR001 mutable-default
    A dataclass field (or function argument) defaulted to a mutable
    literal or a bare constructor call shares ONE instance across every
    construction.  The PR-3 ``Request.sampling`` bug was exactly this —
    every request silently shared one ``SamplingParams``.  Use
    ``field(default_factory=...)``.
RPR002 bare-assert
    ``assert`` on a runtime path is stripped under ``python -O``: the
    check silently vanishes in optimized deployments.  Raise an explicit
    exception (ValueError/RuntimeError/...) instead.  (Tests are not
    linted — pytest asserts are the idiom there.)
RPR003 serveconfig-unvalidated
    Every ``ServeConfig`` field must be validated in ``__post_init__``.
    Unvalidated knobs fail deep inside the engine (or worse, don't);
    the config layer is where a bad value should die with a clear
    message.  A field counts as validated when ``__post_init__``
    mentions it — as a ``self.<field>`` access or as a string literal
    (the registry-loop idiom ``for knob in ("a", "b"): getattr(...)``).
RPR004 jnp-in-loop
    A ``jnp.*`` call inside a Python-level ``for``/``while`` on the
    host path dispatches one XLA op per iteration — the engine's
    per-token loops must stay in numpy / plain Python, batching device
    work into the jitted step functions.  Scoped to ``core/`` (model
    code legitimately builds layer loops that jit traces once).
RPR005 metrics-unsurfaced
    A numeric ``EngineMetrics`` counter that ``summary()`` never reads
    is write-only telemetry: benchmarks and the regression gate can't
    see it, so regressions in what it counts ship silently.
RPR006 jit-in-hot-path
    ``jax.jit(...)`` constructed anywhere but a setup path (module
    level, ``__init__``, ``_build_*``) creates a *fresh* compile cache
    per call — every invocation pays a full XLA compile, the exact
    recompile storm the dispatch sentinel (``analysis/dispatch.py``)
    exists to catch at runtime.  Immediately-invoked ``jax.jit(f)(x)``
    is flagged unconditionally.  Scoped to ``core/``.
RPR007 host-sync-in-loop
    ``.item()`` / ``np.asarray`` / ``jax.device_get`` on device values
    inside a Python-level loop forces one host-device synchronization
    per iteration, serializing the dispatch pipeline the step loop
    relies on.  Hoist the transfer out of the loop and index the result.
    Scoped to ``core/``.
RPR008 pallas-no-contract
    A kernel entry point that launches ``pallas_call`` without any
    explicit argument-contract check (``raise`` on bad shapes/dtypes)
    fails as an opaque Mosaic/XLA error deep in lowering.  Every Pallas
    wrapper must validate its operand shapes/dtypes at entry.  Scoped to
    ``kernels/``.
RPR009 params-unvalidated
    RPR003 generalized to the per-request/-tenant parameter dataclasses:
    every ``SamplingParams`` / ``SLOParams`` / ``TenantTier`` field must
    be mentioned by ``__post_init__`` (same ``self.<field>`` /
    registry-loop string-literal detection).  These objects ride every
    request into the engine's hot paths, where a bad knob surfaces as a
    wrong token or an opaque trace error instead of a config-time
    ValueError.

Run as ``python -m repro.analysis.lint src/ tests/ benchmarks/``
(non-zero exit on findings).  ``--select``/``--ignore`` take
comma-separated codes or names; ``--format github`` emits workflow
annotations for the CI lint job.  A finding is suppressed by a
``# rpr: noqa`` comment on its line (all rules) or
``# rpr: noqa[RPR002,RPR004]`` (those rules only).  Stdlib-only on
purpose: the CI lint job and pre-commit hooks run it without jax/numpy
installed.

Adding a rule: subclass ``Rule``, emit ``Finding``s from ``check``, add
an instance to ``RULES``, and seed ``tests/test_lint.py`` with a fixture
that triggers it (rules must be proven live, not vacuous).
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path
from typing import Dict, Iterator, List, NamedTuple, Optional, Sequence, Set


class Finding(NamedTuple):
    path: str
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _decorator_name(node: ast.expr) -> str:
    """Rightmost dotted name of a decorator, unwrapping calls:
    ``@dataclasses.dataclass(frozen=True)`` -> ``dataclass``."""
    if isinstance(node, ast.Call):
        node = node.func
    while isinstance(node, ast.Attribute):
        node = ast.Name(id=node.attr)
    return node.id if isinstance(node, ast.Name) else ""


def _is_dataclass(node: ast.ClassDef) -> bool:
    return any(_decorator_name(d) == "dataclass" for d in node.decorator_list)


def _call_root(node: ast.expr) -> Optional[str]:
    """Root name of a call target: ``jnp.zeros`` -> ``jnp``."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _callee_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    return f.id if isinstance(f, ast.Name) else ""


class Rule:
    code = ""
    name = ""
    # only lint files whose posix path contains this substring ("" = all)
    scope = ""
    # skip files whose posix path contains this substring ("" = none)
    exclude = ""

    def applies(self, path: str) -> bool:
        p = Path(path).as_posix()
        return self.scope in p and not (self.exclude and self.exclude in p)

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        raise NotImplementedError


_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set,
                     ast.ListComp, ast.DictComp, ast.SetComp)
# calls allowed as defaults: dataclasses.field and immutable constructors
_DEFAULT_CALL_ALLOW = {"field", "frozenset", "tuple", "MappingProxyType"}


class MutableDefault(Rule):
    code = "RPR001"
    name = "mutable-default"

    def _flag(self, node: ast.expr, where: str) -> Iterator[Finding]:
        if isinstance(node, _MUTABLE_LITERALS):
            yield Finding("", node.lineno, self.code,
                          f"mutable literal default on {where}: one instance "
                          "is shared by every call/construction; use "
                          "field(default_factory=...) (or None + init)")
        elif isinstance(node, ast.Call) and \
                _callee_name(node) not in _DEFAULT_CALL_ALLOW:
            yield Finding("", node.lineno, self.code,
                          f"call default on {where} runs ONCE at definition "
                          "time and shares the result (the PR-3 "
                          "Request.sampling bug class); use "
                          "field(default_factory=...)")

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                for default in (*args.defaults, *args.kw_defaults):
                    if default is not None:
                        yield from self._flag(
                            default, f"argument of {node.name}()")
            elif isinstance(node, ast.ClassDef) and _is_dataclass(node):
                for stmt in node.body:
                    value = None
                    if isinstance(stmt, (ast.AnnAssign, ast.Assign)):
                        value = stmt.value
                    if value is not None:
                        yield from self._flag(
                            value, f"dataclass field of {node.name}")


class BareAssert(Rule):
    code = "RPR002"
    name = "bare-assert"
    exclude = "tests/"      # pytest asserts are the idiom there

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Assert):
                yield Finding("", node.lineno, self.code,
                              "bare assert on a runtime path is stripped "
                              "under python -O; raise an explicit exception")


class ServeConfigValidated(Rule):
    code = "RPR003"
    name = "serveconfig-unvalidated"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == "ServeConfig":
                yield from self._check_class(node)

    def _check_class(self, cls: ast.ClassDef) -> Iterator[Finding]:
        fields = {}     # name -> lineno
        post_init = None
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                ann = ast.unparse(stmt.annotation)
                if not ann.startswith("ClassVar"):
                    fields[stmt.target.id] = stmt.lineno
            elif isinstance(stmt, ast.FunctionDef) and \
                    stmt.name == "__post_init__":
                post_init = stmt
        if not fields:
            return
        mentioned = set()
        if post_init is not None:
            for node in ast.walk(post_init):
                if isinstance(node, ast.Attribute) and \
                        isinstance(node.value, ast.Name) and \
                        node.value.id == "self":
                    mentioned.add(node.attr)
                elif isinstance(node, ast.Constant) and \
                        isinstance(node.value, str):
                    mentioned.add(node.value)
        for name, line in sorted(fields.items(), key=lambda kv: kv[1]):
            if name not in mentioned:
                yield Finding(
                    "", line, self.code,
                    f"{cls.name}.{name} is never validated in "
                    "__post_init__: a bad value should die at construction "
                    "with a clear message, not deep inside the engine")


class ParamsValidated(ServeConfigValidated):
    """RPR009: RPR003's contract generalized to the per-request/-tenant
    parameter dataclasses (``SamplingParams``, ``SLOParams``,
    ``TenantTier``) — a field added to any of them without a validation
    mention in ``__post_init__`` ships an unvalidated knob straight into
    the engine's hot paths."""

    code = "RPR009"
    name = "params-unvalidated"
    classes = ("SamplingParams", "SLOParams", "TenantTier")

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name in self.classes:
                yield from self._check_class(node)


class JnpInLoop(Rule):
    code = "RPR004"
    name = "jnp-in-loop"
    scope = "repro/core"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        findings: List[Finding] = []
        rule = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.loop_depth = 0

            def _loop(self, node):
                self.loop_depth += 1
                self.generic_visit(node)
                self.loop_depth -= 1

            visit_For = _loop
            visit_While = _loop

            # a nested function def is traced/called elsewhere; don't
            # charge its body to the enclosing loop
            def _func(self, node):
                saved, self.loop_depth = self.loop_depth, 0
                self.generic_visit(node)
                self.loop_depth = saved

            visit_FunctionDef = _func
            visit_AsyncFunctionDef = _func

            def visit_Call(self, node):
                if self.loop_depth and _call_root(node.func) in ("jnp", "jax"):
                    findings.append(Finding(
                        "", node.lineno, rule.code,
                        f"{ast.unparse(node.func)}() inside a Python-level "
                        "loop dispatches one XLA op per iteration on the "
                        "host path; batch it or use numpy"))
                self.generic_visit(node)

        V().visit(tree)
        yield from findings


class MetricsSurfaced(Rule):
    code = "RPR005"
    name = "metrics-unsurfaced"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == "EngineMetrics":
                yield from self._check_class(node)

    def _check_class(self, cls: ast.ClassDef) -> Iterator[Finding]:
        counters = {}   # numeric field name -> lineno
        summary = None
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                ann = ast.unparse(stmt.annotation)
                if ann in ("int", "float"):
                    counters[stmt.target.id] = stmt.lineno
            elif isinstance(stmt, ast.FunctionDef) and stmt.name == "summary":
                summary = stmt
        read = set()
        if summary is not None:
            for node in ast.walk(summary):
                if isinstance(node, ast.Attribute) and \
                        isinstance(node.value, ast.Name) and \
                        node.value.id == "self":
                    read.add(node.attr)
        for name, line in sorted(counters.items(), key=lambda kv: kv[1]):
            if name not in read:
                yield Finding(
                    "", line, self.code,
                    f"EngineMetrics.{name} is never read in summary(): "
                    "write-only telemetry is invisible to benchmarks and "
                    "the regression gate")


def _is_jit(node: ast.expr) -> bool:
    """``jax.jit`` (dotted, rooted at jax) or a bare ``jit`` name."""
    if isinstance(node, ast.Attribute):
        return node.attr == "jit" and _call_root(node) == "jax"
    return isinstance(node, ast.Name) and node.id == "jit"


_JIT_SETUP_NAMES = ("__init__",)     # plus any function named _build*


class JitInHotPath(Rule):
    code = "RPR006"
    name = "jit-in-hot-path"
    scope = "repro/core"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        findings: List[Finding] = []
        rule = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.stack: List[str] = []
                self.skip: Set[int] = set()

            def _func(self, node):
                self.stack.append(node.name)
                self.generic_visit(node)
                self.stack.pop()

            visit_FunctionDef = _func
            visit_AsyncFunctionDef = _func

            def visit_Call(self, node):
                if isinstance(node.func, ast.Call) and _is_jit(node.func.func):
                    findings.append(Finding(
                        "", node.lineno, rule.code,
                        "jax.jit(f)(...) constructs a jitted wrapper and "
                        "invokes it in one expression: the compile cache is "
                        "thrown away per call, so every invocation pays a "
                        "full XLA compile"))
                    self.skip.add(id(node.func))
                if _is_jit(node.func) and id(node) not in self.skip:
                    in_setup = any(
                        name in _JIT_SETUP_NAMES or name.startswith("_build")
                        for name in self.stack)
                    if self.stack and not in_setup:
                        findings.append(Finding(
                            "", node.lineno, rule.code,
                            f"jax.jit constructed inside "
                            f"{self.stack[-1]}(): a fresh compile cache per "
                            "call is a recompile storm; hoist to module "
                            "level, __init__, or a _build_* method"))
                self.generic_visit(node)

        V().visit(tree)
        yield from findings


# host-sync callables flagged inside loops: attr calls by name, dotted
# calls by (root, attr)
_SYNC_ATTRS = frozenset({"item"})
_SYNC_CALLS = frozenset({("np", "asarray"), ("np", "array"),
                         ("np", "copy"), ("jax", "device_get"),
                         ("numpy", "asarray"), ("numpy", "array")})


class HostSyncInLoop(Rule):
    code = "RPR007"
    name = "host-sync-in-loop"
    scope = "repro/core"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        findings: List[Finding] = []
        rule = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.loop_depth = 0

            def _loop(self, node):
                self.loop_depth += 1
                self.generic_visit(node)
                self.loop_depth -= 1

            visit_For = _loop
            visit_While = _loop

            def _func(self, node):
                saved, self.loop_depth = self.loop_depth, 0
                self.generic_visit(node)
                self.loop_depth = saved

            visit_FunctionDef = _func
            visit_AsyncFunctionDef = _func

            def visit_Call(self, node):
                if self.loop_depth:
                    f = node.func
                    sync = None
                    if isinstance(f, ast.Attribute):
                        if f.attr in _SYNC_ATTRS and not node.args:
                            sync = f".{f.attr}()"
                        elif (_call_root(f), f.attr) in _SYNC_CALLS:
                            sync = ast.unparse(f) + "()"
                    if sync is not None:
                        findings.append(Finding(
                            "", node.lineno, rule.code,
                            f"{sync} inside a Python-level loop forces one "
                            "host-device sync per iteration, serializing "
                            "the dispatch pipeline; hoist the transfer out "
                            "of the loop and index the host copy"))
                self.generic_visit(node)

        V().visit(tree)
        yield from findings


class PallasContract(Rule):
    code = "RPR008"
    name = "pallas-no-contract"
    scope = "repro/kernels"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            launches = any(
                isinstance(sub, ast.Call) and _callee_name(sub) == "pallas_call"
                for sub in ast.walk(node))
            raises = any(isinstance(sub, ast.Raise) for sub in ast.walk(node))
            if launches and not raises:
                yield Finding(
                    "", node.lineno, self.code,
                    f"{node.name}() launches pallas_call with no explicit "
                    "argument-contract check: a bad shape/dtype dies as an "
                    "opaque Mosaic lowering error; validate operands and "
                    "raise at entry")


RULES: Sequence[Rule] = (MutableDefault(), BareAssert(),
                         ServeConfigValidated(), JnpInLoop(),
                         MetricsSurfaced(), JitInHotPath(),
                         HostSyncInLoop(), PallasContract(),
                         ParamsValidated())


def _iter_files(paths: Sequence[str]) -> Iterator[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        else:
            yield path


# "# rpr: noqa" (all rules) or "# rpr: noqa[RPR002,RPR004]" (those only)
_NOQA_RE = re.compile(r"#\s*rpr:\s*noqa(?:\[([A-Za-z0-9_,\s-]+)\])?")


def _suppressions(source: str) -> Dict[int, Optional[Set[str]]]:
    """line number -> suppressed codes (None = every rule)."""
    sup: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(source.splitlines(), 1):
        m = _NOQA_RE.search(line)
        if m:
            codes = m.group(1)
            sup[i] = (None if codes is None else
                      {c.strip().upper() for c in codes.split(",") if c.strip()})
    return sup


def _suppressed(f: Finding, sup: Dict[int, Optional[Set[str]]]) -> bool:
    codes = sup.get(f.line, ())
    return codes is None or f.code in codes


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None,
               ignore: Optional[Sequence[str]] = None) -> List[Finding]:
    rules = [r for r in RULES if select is None or r.code in select
             or r.name in select]
    if ignore:
        rules = [r for r in rules
                 if r.code not in ignore and r.name not in ignore]
    findings: List[Finding] = []
    for file in _iter_files(paths):
        rel = str(file)
        try:
            source = file.read_text()
            tree = ast.parse(source, filename=rel)
        except SyntaxError as e:
            findings.append(Finding(rel, e.lineno or 0, "RPR000",
                                    f"syntax error: {e.msg}"))
            continue
        sup = _suppressions(source)
        for rule in rules:
            if not rule.applies(rel):
                continue
            findings.extend(f._replace(path=rel)
                            for f in rule.check(tree, rel)
                            if not _suppressed(f, sup))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


def _render_github(f: Finding) -> str:
    """GitHub Actions workflow annotation (shows inline on the PR diff)."""
    message = f.message.replace("%", "%25").replace("\n", "%0A")
    return (f"::error file={f.path},line={f.line},"
            f"title={f.code}::{message}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific AST lint (see module docstring for rules)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes/names to run "
                         "(default: all)")
    ap.add_argument("--ignore", default=None,
                    help="comma-separated rule codes/names to skip")
    ap.add_argument("--format", default="text", choices=("text", "github"),
                    help="text: path:line: CODE message; github: workflow "
                         "annotations for the CI lint job")
    args = ap.parse_args(argv)
    select = args.select.split(",") if args.select else None
    ignore = args.ignore.split(",") if args.ignore else None
    findings = lint_paths(args.paths, select, ignore)
    for f in findings:
        print(_render_github(f) if args.format == "github" else f.render())
    n_files = sum(1 for _ in _iter_files(args.paths))
    print(f"{len(findings)} finding(s) in {n_files} file(s) "
          f"[{', '.join(r.code for r in RULES)}]")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
