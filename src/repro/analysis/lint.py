"""Repo-specific AST lint: static rejection of bug classes this repo has
already shipped and fixed.

Generic linters (ruff) catch generic mistakes; each rule here encodes a
*specific* incident or contract from this codebase's history:

RPR001 mutable-default
    A dataclass field (or function argument) defaulted to a mutable
    literal or a bare constructor call shares ONE instance across every
    construction.  The PR-3 ``Request.sampling`` bug was exactly this —
    every request silently shared one ``SamplingParams``.  Use
    ``field(default_factory=...)``.
RPR002 bare-assert
    ``assert`` on a runtime path is stripped under ``python -O``: the
    check silently vanishes in optimized deployments.  Raise an explicit
    exception (ValueError/RuntimeError/...) instead.  (Tests are not
    linted — pytest asserts are the idiom there.)
RPR003 serveconfig-unvalidated
    Every ``ServeConfig`` field must be validated in ``__post_init__``.
    Unvalidated knobs fail deep inside the engine (or worse, don't);
    the config layer is where a bad value should die with a clear
    message.  A field counts as validated when ``__post_init__``
    mentions it — as a ``self.<field>`` access or as a string literal
    (the registry-loop idiom ``for knob in ("a", "b"): getattr(...)``).
RPR004 jnp-in-loop
    A ``jnp.*`` call inside a Python-level ``for``/``while`` on the
    host path dispatches one XLA op per iteration — the engine's
    per-token loops must stay in numpy / plain Python, batching device
    work into the jitted step functions.  Scoped to ``core/`` (model
    code legitimately builds layer loops that jit traces once).
RPR005 metrics-unsurfaced
    A numeric ``EngineMetrics`` counter that ``summary()`` never reads
    is write-only telemetry: benchmarks and the regression gate can't
    see it, so regressions in what it counts ship silently.

Run as ``python -m repro.analysis.lint src/`` (non-zero exit on
findings).  Stdlib-only on purpose: the CI lint job and pre-commit hooks
run it without jax/numpy installed.

Adding a rule: subclass ``Rule``, emit ``Finding``s from ``check``, add
an instance to ``RULES``, and seed ``tests/test_lint.py`` with a fixture
that triggers it (rules must be proven live, not vacuous).
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from typing import Iterator, List, NamedTuple, Optional, Sequence


class Finding(NamedTuple):
    path: str
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _decorator_name(node: ast.expr) -> str:
    """Rightmost dotted name of a decorator, unwrapping calls:
    ``@dataclasses.dataclass(frozen=True)`` -> ``dataclass``."""
    if isinstance(node, ast.Call):
        node = node.func
    while isinstance(node, ast.Attribute):
        node = ast.Name(id=node.attr)
    return node.id if isinstance(node, ast.Name) else ""


def _is_dataclass(node: ast.ClassDef) -> bool:
    return any(_decorator_name(d) == "dataclass" for d in node.decorator_list)


def _call_root(node: ast.expr) -> Optional[str]:
    """Root name of a call target: ``jnp.zeros`` -> ``jnp``."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _callee_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    return f.id if isinstance(f, ast.Name) else ""


class Rule:
    code = ""
    name = ""
    # only lint files whose posix path contains this substring ("" = all)
    scope = ""

    def applies(self, path: str) -> bool:
        return self.scope in Path(path).as_posix()

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        raise NotImplementedError


_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set,
                     ast.ListComp, ast.DictComp, ast.SetComp)
# calls allowed as defaults: dataclasses.field and immutable constructors
_DEFAULT_CALL_ALLOW = {"field", "frozenset", "tuple", "MappingProxyType"}


class MutableDefault(Rule):
    code = "RPR001"
    name = "mutable-default"

    def _flag(self, node: ast.expr, where: str) -> Iterator[Finding]:
        if isinstance(node, _MUTABLE_LITERALS):
            yield Finding("", node.lineno, self.code,
                          f"mutable literal default on {where}: one instance "
                          "is shared by every call/construction; use "
                          "field(default_factory=...) (or None + init)")
        elif isinstance(node, ast.Call) and \
                _callee_name(node) not in _DEFAULT_CALL_ALLOW:
            yield Finding("", node.lineno, self.code,
                          f"call default on {where} runs ONCE at definition "
                          "time and shares the result (the PR-3 "
                          "Request.sampling bug class); use "
                          "field(default_factory=...)")

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                for default in (*args.defaults, *args.kw_defaults):
                    if default is not None:
                        yield from self._flag(
                            default, f"argument of {node.name}()")
            elif isinstance(node, ast.ClassDef) and _is_dataclass(node):
                for stmt in node.body:
                    value = None
                    if isinstance(stmt, (ast.AnnAssign, ast.Assign)):
                        value = stmt.value
                    if value is not None:
                        yield from self._flag(
                            value, f"dataclass field of {node.name}")


class BareAssert(Rule):
    code = "RPR002"
    name = "bare-assert"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Assert):
                yield Finding("", node.lineno, self.code,
                              "bare assert on a runtime path is stripped "
                              "under python -O; raise an explicit exception")


class ServeConfigValidated(Rule):
    code = "RPR003"
    name = "serveconfig-unvalidated"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == "ServeConfig":
                yield from self._check_class(node)

    def _check_class(self, cls: ast.ClassDef) -> Iterator[Finding]:
        fields = {}     # name -> lineno
        post_init = None
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                ann = ast.unparse(stmt.annotation)
                if not ann.startswith("ClassVar"):
                    fields[stmt.target.id] = stmt.lineno
            elif isinstance(stmt, ast.FunctionDef) and \
                    stmt.name == "__post_init__":
                post_init = stmt
        if not fields:
            return
        mentioned = set()
        if post_init is not None:
            for node in ast.walk(post_init):
                if isinstance(node, ast.Attribute) and \
                        isinstance(node.value, ast.Name) and \
                        node.value.id == "self":
                    mentioned.add(node.attr)
                elif isinstance(node, ast.Constant) and \
                        isinstance(node.value, str):
                    mentioned.add(node.value)
        for name, line in sorted(fields.items(), key=lambda kv: kv[1]):
            if name not in mentioned:
                yield Finding(
                    "", line, self.code,
                    f"ServeConfig.{name} is never validated in "
                    "__post_init__: a bad value should die at construction "
                    "with a clear message, not deep inside the engine")


class JnpInLoop(Rule):
    code = "RPR004"
    name = "jnp-in-loop"
    scope = "repro/core"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        findings: List[Finding] = []
        rule = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.loop_depth = 0

            def _loop(self, node):
                self.loop_depth += 1
                self.generic_visit(node)
                self.loop_depth -= 1

            visit_For = _loop
            visit_While = _loop

            # a nested function def is traced/called elsewhere; don't
            # charge its body to the enclosing loop
            def _func(self, node):
                saved, self.loop_depth = self.loop_depth, 0
                self.generic_visit(node)
                self.loop_depth = saved

            visit_FunctionDef = _func
            visit_AsyncFunctionDef = _func

            def visit_Call(self, node):
                if self.loop_depth and _call_root(node.func) in ("jnp", "jax"):
                    findings.append(Finding(
                        "", node.lineno, rule.code,
                        f"{ast.unparse(node.func)}() inside a Python-level "
                        "loop dispatches one XLA op per iteration on the "
                        "host path; batch it or use numpy"))
                self.generic_visit(node)

        V().visit(tree)
        yield from findings


class MetricsSurfaced(Rule):
    code = "RPR005"
    name = "metrics-unsurfaced"

    def check(self, tree: ast.Module, path: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == "EngineMetrics":
                yield from self._check_class(node)

    def _check_class(self, cls: ast.ClassDef) -> Iterator[Finding]:
        counters = {}   # numeric field name -> lineno
        summary = None
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                ann = ast.unparse(stmt.annotation)
                if ann in ("int", "float"):
                    counters[stmt.target.id] = stmt.lineno
            elif isinstance(stmt, ast.FunctionDef) and stmt.name == "summary":
                summary = stmt
        read = set()
        if summary is not None:
            for node in ast.walk(summary):
                if isinstance(node, ast.Attribute) and \
                        isinstance(node.value, ast.Name) and \
                        node.value.id == "self":
                    read.add(node.attr)
        for name, line in sorted(counters.items(), key=lambda kv: kv[1]):
            if name not in read:
                yield Finding(
                    "", line, self.code,
                    f"EngineMetrics.{name} is never read in summary(): "
                    "write-only telemetry is invisible to benchmarks and "
                    "the regression gate")


RULES: Sequence[Rule] = (MutableDefault(), BareAssert(),
                         ServeConfigValidated(), JnpInLoop(),
                         MetricsSurfaced())


def _iter_files(paths: Sequence[str]) -> Iterator[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        else:
            yield path


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None) -> List[Finding]:
    rules = [r for r in RULES if select is None or r.code in select
             or r.name in select]
    findings: List[Finding] = []
    for file in _iter_files(paths):
        rel = str(file)
        try:
            tree = ast.parse(file.read_text(), filename=rel)
        except SyntaxError as e:
            findings.append(Finding(rel, e.lineno or 0, "RPR000",
                                    f"syntax error: {e.msg}"))
            continue
        for rule in rules:
            if not rule.applies(rel):
                continue
            findings.extend(f._replace(path=rel)
                            for f in rule.check(tree, rel))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific AST lint (see module docstring for rules)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes/names to run "
                         "(default: all)")
    args = ap.parse_args(argv)
    select = args.select.split(",") if args.select else None
    findings = lint_paths(args.paths, select)
    for f in findings:
        print(f.render())
    n_files = sum(1 for _ in _iter_files(args.paths))
    print(f"{len(findings)} finding(s) in {n_files} file(s) "
          f"[{', '.join(r.code for r in RULES)}]")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
