"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block
applied every 6th layer-unit with per-invocation LoRA deltas.

Layer-unit layout (cfg.n_layers = 81): 13 groups x (5 mamba + 1 shared
attn) + 3 trailing mamba = 68 mamba units + 13 attn invocations.
The shared block takes concat(hidden, initial_embedding) [2D] as input
(Zamba's re-injection of the embedding stream).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm
from repro.models.layers import (
    dense_init, flash_attention, mlp_apply, mlp_init, rms_norm, rope,
)
from repro.models.sharding import constrain
from repro.models.transformer import (
    default_decode_attn, gqa_layout, pad_vocab, unembed,
)

def group_structure(cfg):
    """(n_attn, n_mamba, n_grouped, n_trailing, n_per_group).

    Every cfg.shared_attn_every-th layer-unit is the shared attn block;
    full zamba2-7b: 81 units -> 13 attn + 68 mamba (13x5 grouped + 3 trail).
    """
    n_per_group = cfg.shared_attn_every - 1
    n_attn = cfg.n_layers // cfg.shared_attn_every
    n_mamba = cfg.n_layers - n_attn
    n_grouped = n_attn * n_per_group
    n_trailing = n_mamba - n_grouped
    return n_attn, n_mamba, n_grouped, n_trailing, n_per_group


def init_params(cfg, key, dtype=jnp.float32, tp: int = 1):
    D, hd = cfg.d_model, cfg.head_dim
    H_p, KV_p, q_map, _, _ = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    n_attn, n_mamba, _, _, _ = group_structure(cfg)
    Vp = pad_vocab(cfg.vocab_size)
    r = cfg.shared_attn_lora_rank
    ks = iter(jax.random.split(key, 24))

    wq = dense_init(next(ks), (2 * D, H_p, hd), 2 * D, dtype)
    wq = wq * jnp.asarray(q_map >= 0, dtype)[None, :, None]
    shared = {
        "ln1": jnp.zeros((2 * D,), dtype),
        "wq": wq,
        "wk": dense_init(next(ks), (2 * D, cfg.n_kv_heads, hd), 2 * D, dtype),
        "wv": dense_init(next(ks), (2 * D, cfg.n_kv_heads, hd), 2 * D, dtype),
        "wo": dense_init(next(ks), (H_p, hd, D), H_p * hd, dtype,
                         1.0 / math.sqrt(2 * n_attn)),
        "ln2": jnp.zeros((D,), dtype),
        "mlp": mlp_init(next(ks), D, cfg.d_ff, cfg.mlp_act, dtype,
                        1.0 / math.sqrt(2 * n_attn)),
    }
    lora = {
        "qa": dense_init(next(ks), (n_attn, 2 * D, r), 2 * D, dtype),
        "qb": jnp.zeros((n_attn, r, H_p * hd), dtype),
        "ka": dense_init(next(ks), (n_attn, 2 * D, r), 2 * D, dtype),
        "kb": jnp.zeros((n_attn, r, cfg.n_kv_heads * hd), dtype),
        "va": dense_init(next(ks), (n_attn, 2 * D, r), 2 * D, dtype),
        "vb": jnp.zeros((n_attn, r, cfg.n_kv_heads * hd), dtype),
    }
    return {
        "embed": (jax.random.normal(next(ks), (Vp, D), jnp.float32) * 0.02).astype(dtype),
        "mamba": ssm.mamba2_init(next(ks), cfg, dtype, stack=(n_mamba,)),
        "shared": shared,
        "lora": lora,
        "ln_f": jnp.zeros((D,), dtype),
    }


def _shared_qkv(cfg, shared, lora_i, h2, lay):
    """h2 [..., 2D] -> q [..., H_p, hd], k/v [..., KV, hd] with LoRA deltas."""
    H_p, KV_p, _, kv_map, _ = gqa_layout(cfg.n_heads, cfg.n_kv_heads, 1)
    hd = cfg.head_dim
    q = jnp.einsum("...d,dhk->...hk", h2, shared["wq"])
    k = jnp.einsum("...d,dhk->...hk", h2, shared["wk"])
    v = jnp.einsum("...d,dhk->...hk", h2, shared["wv"])
    dq = jnp.einsum("...d,dr,re->...e", h2, lora_i["qa"], lora_i["qb"])
    dk = jnp.einsum("...d,dr,re->...e", h2, lora_i["ka"], lora_i["kb"])
    dv = jnp.einsum("...d,dr,re->...e", h2, lora_i["va"], lora_i["vb"])
    q = q + dq.reshape(dq.shape[:-1] + (q.shape[-2], hd))
    k = k + dk.reshape(dk.shape[:-1] + (cfg.n_kv_heads, hd))
    v = v + dv.reshape(dv.shape[:-1] + (cfg.n_kv_heads, hd))
    return q, k, v


def _shared_block_seq(cfg, lay, shared, lora_i, x, x0, positions, *,
                      collect_kv=False, policy=None):
    """Full-seq shared attention block. x/x0 [B,T,D]."""
    H_p, KV_p, _, kv_map, head_mask = lay
    h2 = rms_norm(jnp.concatenate([x, x0], axis=-1), shared["ln1"], cfg.norm_eps)
    q, k, v = _shared_qkv(cfg, shared, lora_i, h2, lay)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    ke = jnp.take(k, jnp.asarray(kv_map), axis=-2)
    ve = jnp.take(v, jnp.asarray(kv_map), axis=-2)
    o = flash_attention(q, ke, ve, q_positions=positions,
                        kv_positions=positions, scale=1.0 / math.sqrt(cfg.head_dim),
                        causal=True)
    o = o * jnp.asarray(head_mask, o.dtype)[:, None]
    attn = jnp.einsum("bthk,hkd->btd", o, shared["wo"])
    x = x + attn
    y = mlp_apply(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps), cfg.mlp_act)
    x = x + y
    return x, (ke, ve) if collect_kv else None


def forward_seq(params, cfg, tokens, *, tp=1, policy=None, remat=False,
                collect_kv=False, chunk=64, conv0=None, ssm0=None,
                start_pos=0):
    """Full-sequence forward (train / prefill).

    Returns (hidden [B,T,D], kv list or None, (conv_states, ssm_states)).
    """
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    n_attn, n_mamba, n_grouped, n_trailing, n_per_group = group_structure(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    if policy is not None:
        x = constrain(x, policy, "batch", "seq", None)
    x0 = x
    B, T, D = x.shape
    positions = start_pos + jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    dt = jax.tree.leaves(params)[0].dtype
    cs_shape, ss_shape = ssm.mamba2_state_shapes(cfg, B)
    if conv0 is None:
        conv0 = {k: jnp.zeros((n_mamba,) + v, dt) for k, v in cs_shape.items()}
    ssm0 = ssm0 if ssm0 is not None else jnp.zeros((n_mamba,) + ss_shape, jnp.float32)

    group = lambda a: a[:n_grouped].reshape((n_attn, n_per_group) + a.shape[1:])
    mg = jax.tree.map(group, params["mamba"])
    cg = jax.tree.map(group, conv0)
    sg = group(ssm0)

    def mamba_scan(x, mp, c0, s0):
        def mbody(xc, xs):
            lp, c, s = xs
            xc, c2, s2 = ssm.mamba2_block(lp, cfg, xc, c, s, chunk=chunk)
            return xc, (c2, s2)
        if remat:
            mbody = jax.checkpoint(
                mbody, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return jax.lax.scan(mbody, x, (mp, c0, s0))

    def group_body(xc, xs):
        mp, c0, s0, lora_i = xs
        xc, (c2, s2) = mamba_scan(xc, mp, c0, s0)
        xc, kv = _shared_block_seq(cfg, lay, params["shared"], lora_i, xc, x0,
                                   positions, collect_kv=collect_kv)
        if policy is not None:
            xc = constrain(xc, policy, "batch", "seq", None)
        return xc, (c2, s2, kv)

    x, (cg2, sg2, kv) = jax.lax.scan(group_body, x, (mg, cg, sg, params["lora"]))
    mt = jax.tree.map(lambda a: a[n_grouped:], params["mamba"])
    ct0 = jax.tree.map(lambda a: a[n_grouped:], conv0)
    x, (ct2, st2) = mamba_scan(x, mt, ct0, ssm0[n_grouped:])
    ungroup = lambda g, t: jnp.concatenate([g.reshape((n_grouped,) + g.shape[2:]), t], 0)
    conv_out = jax.tree.map(ungroup, cg2, ct2)
    ssm_out = ungroup(sg2, st2)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, kv, (conv_out, ssm_out)


def train_logits(params, cfg, batch, *, tp=1, policy=None, moe_fn=None,
                 remat=False):
    del moe_fn
    hidden, _, _ = forward_seq(params, cfg, batch["tokens"], tp=tp,
                               policy=policy, remat=remat)
    return unembed(params, cfg, hidden, policy), jnp.float32(0.0)


def prefill(params, cfg, tokens, *, tp=1, policy=None):
    """Returns (last_logits, (k, v) [n_attn, B, S, KV_p, hd], (conv, ssm))."""
    hidden, kv, states = forward_seq(params, cfg, tokens, tp=tp, policy=policy,
                                     collect_kv=True)
    logits = unembed(params, cfg, hidden[:, -1], policy)
    return logits, kv, states


def decode(params, cfg, tokens, conv_states, ssm_states, k_pages, v_pages,
           block_table, seq_lens, *, active=None, attn_fn=None, tp=1,
           policy=None):
    """One token step. tokens [B]; pages [n_attn, N, ps, KV_p, hd].

    Returns (logits, (conv, ssm), (k_pages, v_pages)).
    """
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    H_p, KV_p, _, kv_map, head_mask = lay
    attn_fn = attn_fn or default_decode_attn
    n_attn, n_mamba, n_grouped, n_trailing, n_per_group = group_structure(cfg)
    act = active if active is not None else jnp.ones((tokens.shape[0],), bool)
    x = jnp.take(params["embed"], tokens, axis=0)           # [B, D]
    if policy is not None:
        x = constrain(x, policy, "batch", None)
    x0 = x
    pos = seq_lens

    group = lambda a: a[:n_grouped].reshape((n_attn, n_per_group) + a.shape[1:])
    mg = jax.tree.map(group, params["mamba"])
    cg = jax.tree.map(group, conv_states)
    sg = group(ssm_states)

    def mamba_scan(x, mp, c0, s0):
        def mbody(xc, xs):
            lp, c, s = xs
            xc, c2, s2 = ssm.mamba2_decode(lp, cfg, xc, c, s)
            return xc, (c2, s2)
        return jax.lax.scan(mbody, x, (mp, c0, s0))

    def group_body(xc, xs):
        mp, c0, s0, lora_i, kpg, vpg = xs
        xc, (c2, s2) = mamba_scan(xc, mp, c0, s0)
        h2 = rms_norm(jnp.concatenate([xc, x0], axis=-1),
                      params["shared"]["ln1"], cfg.norm_eps)
        q, k, v = _shared_qkv(cfg, params["shared"], lora_i, h2, lay)
        q = rope(q[:, None], pos[:, None], cfg.rope_theta)
        k = rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        ke = jnp.take(k, jnp.asarray(kv_map), axis=-2)
        ve = jnp.take(v, jnp.asarray(kv_map), axis=-2)
        o, kpg, vpg = attn_fn(q, ke, ve, kpg, vpg, block_table, seq_lens, act,
                              scale=1.0 / math.sqrt(cfg.head_dim), window=None,
                              attn_softcap=None)
        o = o[:, 0] * jnp.asarray(head_mask, o.dtype)[:, None]
        xc = xc + jnp.einsum("bhk,hkd->bd", o, params["shared"]["wo"])
        y = mlp_apply(params["shared"]["mlp"],
                      rms_norm(xc, params["shared"]["ln2"], cfg.norm_eps),
                      cfg.mlp_act)
        xc = xc + y
        if policy is not None:
            xc = constrain(xc, policy, "batch", None)
        return xc, (c2, s2, kpg, vpg)

    x, (cg2, sg2, k_pages, v_pages) = jax.lax.scan(
        group_body, x, (mg, cg, sg, params["lora"], k_pages, v_pages))
    mt = jax.tree.map(lambda a: a[n_grouped:], params["mamba"])
    ct0 = jax.tree.map(lambda a: a[n_grouped:], conv_states)
    x, (ct2, st2) = mamba_scan(x, mt, ct0, ssm_states[n_grouped:])
    ungroup = lambda g, t: jnp.concatenate([g.reshape((n_grouped,) + g.shape[2:]), t], 0)
    conv_out = jax.tree.map(ungroup, cg2, ct2)
    ssm_out = ungroup(sg2, st2)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(params, cfg, x, policy), (conv_out, ssm_out), (k_pages, v_pages)
