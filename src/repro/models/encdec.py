"""Seamless-M4T-style encoder-decoder backbone.

The speech frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings [B, S_enc, D]. Phases for the serving engine:
"prefill" = encoder pass + cross-KV build + decoder prompt prefill;
"decode" = decoder token steps (paged self-KV + fixed cross-KV).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    dense_init, flash_attention, mlp_apply, mlp_init, rms_norm, rope,
)
from repro.models.sharding import constrain
from repro.models.transformer import (
    default_decode_attn, gqa_layout, pad_vocab, unembed,
)


def _attn_params(key, cfg, D_in, lay, dtype, out_scale):
    H_p, KV_p, q_map, _, _ = lay
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    wq = dense_init(k1, (D_in, H_p, hd), D_in, dtype)
    wq = wq * jnp.asarray(q_map >= 0, dtype)[None, :, None]
    return {
        "wq": wq,
        "wk": dense_init(k2, (D_in, cfg.n_kv_heads, hd), D_in, dtype),
        "wv": dense_init(k3, (D_in, cfg.n_kv_heads, hd), D_in, dtype),
        "wo": dense_init(k4, (H_p, hd, cfg.d_model), H_p * hd, dtype, out_scale),
    }


def init_params(cfg, key, dtype=jnp.float32, tp: int = 1):
    D = cfg.d_model
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    Vp = pad_vocab(cfg.vocab_size)
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    ks = iter(jax.random.split(key, 12))
    s_enc = 1.0 / math.sqrt(2 * Le)
    s_dec = 1.0 / math.sqrt(2 * Ld)

    def stack_attn(key, L, scale):
        keys = jax.random.split(key, L)
        ps = [_attn_params(k, cfg, D, lay, dtype, scale) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    def stack_mlp(key, L, scale):
        keys = jax.random.split(key, L)
        ps = [mlp_init(k, D, cfg.d_ff, cfg.mlp_act, dtype, scale) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    enc_blocks = {
        "ln1": jnp.zeros((Le, D), dtype),
        "attn": stack_attn(next(ks), Le, s_enc),
        "ln2": jnp.zeros((Le, D), dtype),
        "mlp": stack_mlp(next(ks), Le, s_enc),
    }
    dec_blocks = {
        "ln1": jnp.zeros((Ld, D), dtype),
        "self": stack_attn(next(ks), Ld, s_dec),
        "lnx": jnp.zeros((Ld, D), dtype),
        "cross": stack_attn(next(ks), Ld, s_dec),
        "ln2": jnp.zeros((Ld, D), dtype),
        "mlp": stack_mlp(next(ks), Ld, s_dec),
    }
    return {
        "embed": (jax.random.normal(next(ks), (Vp, D), jnp.float32) * 0.02).astype(dtype),
        "enc_blocks": enc_blocks,
        "enc_ln_f": jnp.zeros((D,), dtype),
        "dec_blocks": dec_blocks,
        "ln_f": jnp.zeros((D,), dtype),
    }


def _mha(cfg, lay, ap, xq, xkv, q_pos, kv_pos, *, causal, kv_valid_len=None):
    H_p, KV_p, _, kv_map, head_mask = lay
    q = jnp.einsum("btd,dhk->bthk", xq, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, ap["wv"])
    q = rope(q, q_pos, cfg.rope_theta)
    k = rope(k, kv_pos, cfg.rope_theta)
    ke = jnp.take(k, jnp.asarray(kv_map), axis=-2)
    ve = jnp.take(v, jnp.asarray(kv_map), axis=-2)
    o = flash_attention(q, ke, ve, q_positions=q_pos, kv_positions=kv_pos,
                        kv_valid_len=kv_valid_len,
                        scale=1.0 / math.sqrt(cfg.head_dim), causal=causal)
    o = o * jnp.asarray(head_mask, o.dtype)[:, None]
    return jnp.einsum("bthk,hkd->btd", o, ap["wo"])


def encode(params, cfg, frames, *, policy=None, enc_valid_len=None):
    """frames [B, S_enc, D] (stub frontend output) -> [B, S_enc, D]."""
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, 1)
    B, S, D = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = frames
    if policy is not None:
        x = constrain(x, policy, "batch", "seq", None)

    def body(xc, lp):
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        xc = xc + _mha(cfg, lay, lp["attn"], h, h, pos, pos, causal=False,
                       kv_valid_len=enc_valid_len)
        h2 = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + mlp_apply(lp["mlp"], h2, cfg.mlp_act)
        if policy is not None:
            xc = constrain(xc, policy, "batch", "seq", None)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def build_cross_kv(params, cfg, enc_out, tp=1):
    """Per-decoder-layer cross K/V from encoder output.

    Returns (xk, xv) [Ld, B, S_enc, KV_p, hd] (positions not roped —
    cross attention uses raw keys; rope is self-attn only here).
    """
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    _, KV_p, _, kv_map, _ = lay

    def body(_, ap):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, ap["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, ap["wv"])
        ke = jnp.take(k, jnp.asarray(kv_map), axis=-2)
        ve = jnp.take(v, jnp.asarray(kv_map), axis=-2)
        return None, (ke, ve)

    _, (xk, xv) = jax.lax.scan(body, None, params["dec_blocks"]["cross"])
    return xk, xv


def _decoder_seq(params, cfg, tokens, enc_out, *, tp=1, policy=None,
                 collect_kv=False, enc_valid_len=None, start_pos=0):
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    H_p, KV_p, _, kv_map, head_mask = lay
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, D = x.shape
    pos = start_pos + jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    S = enc_out.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if policy is not None:
        x = constrain(x, policy, "batch", "seq", None)

    def body(xc, lp):
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        # self attention (causal, roped) — collect expanded k/v for cache
        q = jnp.einsum("btd,dhk->bthk", h, lp["self"]["wq"])
        k = jnp.einsum("btd,dhk->bthk", h, lp["self"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", h, lp["self"]["wv"])
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        ke = jnp.take(k, jnp.asarray(kv_map), axis=-2)
        ve = jnp.take(v, jnp.asarray(kv_map), axis=-2)
        o = flash_attention(q, ke, ve, q_positions=pos, kv_positions=pos,
                            scale=1.0 / math.sqrt(cfg.head_dim), causal=True)
        o = o * jnp.asarray(head_mask, o.dtype)[:, None]
        xc = xc + jnp.einsum("bthk,hkd->btd", o, lp["self"]["wo"])
        # cross attention (non-causal over encoder output, un-roped)
        hx = rms_norm(xc, lp["lnx"], cfg.norm_eps)
        qx = jnp.einsum("btd,dhk->bthk", hx, lp["cross"]["wq"])
        kx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"])
        vx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"])
        kxe = jnp.take(kx, jnp.asarray(kv_map), axis=-2)
        vxe = jnp.take(vx, jnp.asarray(kv_map), axis=-2)
        ox = flash_attention(qx, kxe, vxe, q_positions=pos, kv_positions=enc_pos,
                             kv_valid_len=enc_valid_len,
                             scale=1.0 / math.sqrt(cfg.head_dim), causal=False)
        ox = ox * jnp.asarray(head_mask, ox.dtype)[:, None]
        xc = xc + jnp.einsum("bthk,hkd->btd", ox, lp["cross"]["wo"])
        h2 = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + mlp_apply(lp["mlp"], h2, cfg.mlp_act)
        if policy is not None:
            xc = constrain(xc, policy, "batch", "seq", None)
        return xc, (ke, ve) if collect_kv else None

    x, kv = jax.lax.scan(body, x, params["dec_blocks"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps), kv


def train_logits(params, cfg, batch, *, tp=1, policy=None, moe_fn=None,
                 remat=False):
    """batch: frames [B, S_enc, D], tokens [B, T_dec]."""
    del moe_fn, remat
    enc_out = encode(params, cfg, batch["frames"], policy=policy)
    hidden, _ = _decoder_seq(params, cfg, batch["tokens"], enc_out, tp=tp,
                             policy=policy)
    return unembed(params, cfg, hidden, policy), jnp.float32(0.0)


def prefill(params, cfg, frames, tokens, *, tp=1, policy=None):
    """Encoder pass + decoder prompt prefill.

    Returns (last_logits, (k, v) self-KV [Ld,B,T,KV_p,hd],
             (xk, xv) cross-KV [Ld,B,S_enc,KV_p,hd]).
    """
    enc_out = encode(params, cfg, frames, policy=policy)
    hidden, kv = _decoder_seq(params, cfg, tokens, enc_out, tp=tp,
                              policy=policy, collect_kv=True)
    cross = build_cross_kv(params, cfg, enc_out, tp=tp)
    return unembed(params, cfg, hidden[:, -1], policy), kv, cross


def decode(params, cfg, tokens, k_pages, v_pages, cross_k, cross_v,
           block_table, seq_lens, *, active=None, attn_fn=None, tp=1,
           policy=None, enc_valid_len=None):
    """One decoder token step.

    tokens [B]; pages [Ld, N, ps, KV_p, hd]; cross_k/v [Ld, B, S_enc, KV_p, hd].
    Returns (logits, (k_pages, v_pages)).
    """
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    H_p, KV_p, _, kv_map, head_mask = lay
    attn_fn = attn_fn or default_decode_attn
    act = active if active is not None else jnp.ones((tokens.shape[0],), bool)
    x = jnp.take(params["embed"], tokens, axis=0)
    if policy is not None:
        x = constrain(x, policy, "batch", None)
    B = x.shape[0]
    pos = seq_lens
    S = cross_k.shape[2]
    enc_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(xc, xs):
        lp, kpg, vpg, xk, xv = xs
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, lp["self"]["wq"])
        k = jnp.einsum("bd,dhk->bhk", h, lp["self"]["wk"])
        v = jnp.einsum("bd,dhk->bhk", h, lp["self"]["wv"])
        q = rope(q[:, None], pos[:, None], cfg.rope_theta)
        k = rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        ke = jnp.take(k, jnp.asarray(kv_map), axis=-2)
        ve = jnp.take(v, jnp.asarray(kv_map), axis=-2)
        o, kpg, vpg = attn_fn(q, ke, ve, kpg, vpg, block_table, seq_lens, act,
                              scale=1.0 / math.sqrt(cfg.head_dim), window=None,
                              attn_softcap=None)
        o = o[:, 0] * jnp.asarray(head_mask, o.dtype)[:, None]
        xc = xc + jnp.einsum("bhk,hkd->bd", o, lp["self"]["wo"])
        hx = rms_norm(xc, lp["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bd,dhk->bhk", hx, lp["cross"]["wq"])[:, None]
        ox = flash_attention(qx, xk, xv, q_positions=pos[:, None],
                             kv_positions=enc_pos, kv_valid_len=enc_valid_len,
                             scale=1.0 / math.sqrt(cfg.head_dim), causal=False)
        ox = ox[:, 0] * jnp.asarray(head_mask, ox.dtype)[:, None]
        xc = xc + jnp.einsum("bhk,hkd->bd", ox, lp["cross"]["wo"])
        h2 = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + mlp_apply(lp["mlp"], h2, cfg.mlp_act)
        if policy is not None:
            xc = constrain(xc, policy, "batch", None)
        return xc, (kpg, vpg)

    x, (k_pages, v_pages) = jax.lax.scan(
        body, x, (params["dec_blocks"], k_pages, v_pages, cross_k, cross_v))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(params, cfg, x, policy), (k_pages, v_pages)
