"""Model registry: maps each architecture family to its module.

Families expose (at least): init_params, train_logits; decoder families
add prefill/decode (+ mixed for the transformer family). Cache handling
is family-specific; `cache_kind` tells the engine/launcher what to build:
  paged        — transformer (dense/moe/vlm): paged KV
  paged+cross  — encdec: paged self-KV + dense cross-KV
  paged+state  — hybrid: paged KV (shared attn) + SSM/conv states
  state        — ssm (rwkv6): recurrent state slots only
"""
from dataclasses import dataclass
from typing import Any

from repro.configs import get_config
from repro.models import encdec, hybrid, rwkv, transformer

FAMILY_MODULE = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "encdec": encdec,
    "hybrid": hybrid,
    "ssm": rwkv,
}

CACHE_KIND = {
    "dense": "paged",
    "moe": "paged",
    "vlm": "paged",
    "encdec": "paged+cross",
    "hybrid": "paged+state",
    "ssm": "state",
}


@dataclass(frozen=True)
class Model:
    name: str
    cfg: Any
    module: Any
    cache_kind: str

    def init(self, key, dtype=None, tp: int = 1):
        import jax.numpy as jnp
        return self.module.init_params(self.cfg, key, dtype or jnp.float32, tp=tp)

    def train_logits(self, params, batch, **kw):
        return self.module.train_logits(params, self.cfg, batch, **kw)


def get_model(arch: str, cfg=None) -> Model:
    cfg = cfg if cfg is not None else get_config(arch)
    mod = FAMILY_MODULE[cfg.family]
    return Model(name=arch, cfg=cfg, module=mod, cache_kind=CACHE_KIND[cfg.family])
