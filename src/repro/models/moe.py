"""Mixture-of-Experts layer: top-k routing, capacity-based scatter dispatch.

The `moe_apply` function is written over *local* arrays so it can run
either directly (CPU tests, no mesh) or inside a shard_map wrapper
(production): the caller passes the expert weights it owns plus its
expert-id range (EP over the model axis) or full range with F-sliced
weights (expert tensor-parallelism, used when n_experts < model axis, e.g.
grok-1's 8 experts on a 16-way axis). Cross-shard combine = one psum of
[T, D] done by the caller — the same all-reduce shape dense TP MLPs pay.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def moe_apply(
    lp: dict,                  # router [D, E]; w_gate/w_up [E_loc, D, F_loc]; w_down [E_loc, F_loc, D]
    x,                         # [T, D] token activations
    *,
    n_experts: int,
    top_k: int,
    act,                       # callable activation (on gate)
    expert_offset: int = 0,    # first expert id owned locally
    capacity_factor: float = 1.25,
    renorm_gates: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [T, D] local partial output, aux load-balance loss)."""
    T, D = x.shape
    E, K = n_experts, top_k
    E_loc = lp["w_gate"].shape[0]

    router_logits = jnp.einsum("td,de->te", x, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)                 # [T, E]
    gates, eidx = jax.lax.top_k(probs, K)                          # [T, K]
    if renorm_gates:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    C = max(1, int(math.ceil(T * K / E * capacity_factor)))

    flat_e = eidx.reshape(-1)                                      # [T*K]
    onehot = (flat_e[:, None] == jnp.arange(E, dtype=flat_e.dtype)[None]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                           # [T*K, E]
    my_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # [T*K]

    local_e = flat_e - expert_offset
    keep = (my_pos < C) & (local_e >= 0) & (local_e < E_loc)
    n_slots = E_loc * C
    slot = jnp.where(keep, local_e * C + my_pos, n_slots)          # overflow -> trash

    x_rep = jnp.repeat(x, K, axis=0)                               # [T*K, D]
    buf = jnp.zeros((n_slots + 1, D), x.dtype).at[slot].set(x_rep, mode="drop")
    h = buf[:n_slots].reshape(E_loc, C, D)

    g = act(jnp.einsum("ecd,edf->ecf", h, lp["w_gate"]))
    if "w_up" in lp:
        g = g * jnp.einsum("ecd,edf->ecf", h, lp["w_up"])
    y_exp = jnp.einsum("ecf,efd->ecd", g, lp["w_down"]).reshape(n_slots, D)
    y_exp = jnp.concatenate([y_exp, jnp.zeros((1, D), y_exp.dtype)], axis=0)

    y_tok = y_exp[slot] * gates.reshape(-1, 1).astype(y_exp.dtype)  # [T*K, D]
    y = y_tok.reshape(T, K, D).sum(axis=1)

    # Switch-style load-balancing aux loss (computed over the full router).
    frac_tokens = jnp.mean(onehot.astype(jnp.float32), axis=0) * (E / K)
    mean_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * mean_probs) / E  # = sum(f_e * P_e) * E / E
    return y, aux


def moe_init(key, cfg, dtype, stack=()):
    """Expert + router weights. Gated (w_gate/w_up) unless act == gelu_mlp."""
    from repro.models.layers import dense_init
    D, F, E, L = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.n_layers
    ks = jax.random.split(key, 4)
    s = tuple(stack)
    p = {
        "router": dense_init(ks[0], s + (D, E), D, dtype),
        "w_gate": dense_init(ks[1], s + (E, D, F), D, dtype),
        "w_up": dense_init(ks[2], s + (E, D, F), D, dtype),
        "w_down": dense_init(ks[3], s + (E, F, D), F, dtype, scale=1.0 / math.sqrt(2 * L)),
    }
    return p
