"""Core layer primitives: norms, RoPE, flash attention (pure-jnp online
softmax over KV blocks), paged attention reference, MLP variants, init.

All attention here is the XLA-native path (used for training, the dry-run,
and as the oracle for the Pallas kernels in repro.kernels).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------- norms ----
def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def head_rms_norm(x, w, eps=1e-6):
    """Per-head RMS norm over the last (head_dim) axis (qwen3 qk-norm)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def rope(x, positions, theta=10_000.0):
    """x: [..., T, H, d]; positions: [..., T] (broadcastable)."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv          # [..., T, d/2]
    cos = jnp.cos(ang)[..., None, :]                               # [..., T, 1, d/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits, cap):
    return jnp.tanh(logits / cap) * cap


# ------------------------------------------------- flash attention (jnp) ----
def flash_attention(
    q,                      # [B, Tq, H, d]  (already scaled is NOT assumed)
    k,                      # [B, Tk, KV, d]
    v,                      # [B, Tk, KV, d]
    *,
    q_positions,            # [B, Tq] int32
    kv_positions,           # [B, Tk] int32
    kv_valid_len=None,      # [B] int32 (positions >= len masked); None = all
    scale: float,
    causal: bool = True,
    window=None,            # None | int | [B?] per-example? -> int or [Tq-broadcast]
    window_per_layer=None,  # scalar jnp value overriding window (scan-friendly)
    attn_softcap: Optional[float] = None,
    block_kv: int = 512,
    _return_lse: bool = False,
    k_scale=None,           # [B, Tk, KV, 1] dequant scales (int8 KV cache)
    v_scale=None,
):
    """Online-softmax attention over KV blocks; O(Tq * block) live memory.

    GQA is handled by folding query heads into groups of the KV heads:
    H must be a multiple of KV. With _return_lse, also returns the
    log-normalizer [B, KV, G, Tq] (for the custom backward).
    """
    B, Tq, H, d = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError(f"query heads H={H} must be a multiple of KV={KV}")
    G = H // KV

    orig_tk = Tk
    pad = (-Tk) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)), constant_values=2**30)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Tk = Tk + pad
    nblk = Tk // block_kv

    qg = q.reshape(B, Tq, KV, G, d).astype(jnp.float32) * scale
    kb_all = k.reshape(B, nblk, block_kv, KV, d)
    vb_all = v.reshape(B, nblk, block_kv, KV, d)
    pos_all = kv_positions.reshape(B, nblk, block_kv)
    if k_scale is not None:
        ks_all = k_scale.reshape(B, nblk, block_kv, KV, 1).transpose(1, 0, 2, 3, 4)
        vs_all = v_scale.reshape(B, nblk, block_kv, KV, 1).transpose(1, 0, 2, 3, 4)

    if window_per_layer is not None:
        window = window_per_layer

    def flash_vmem_body(carry, xs):
        m, l, acc = carry
        if k_scale is not None:
            kb, vb, posb, ksb, vsb = xs         # int8 codes + scales
            kb = kb.astype(jnp.float32) * ksb   # dequant in "VMEM"
            vb = vb.astype(jnp.float32) * vsb
        else:
            kb, vb, posb = xs                   # [B, blk, KV, d], [B, blk]
        # logits: [B, KV, G, Tq, blk]
        logits = jnp.einsum(
            "bqKgd,bkKd->bKgqk", qg, kb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        if attn_softcap is not None:
            logits = softcap(logits, attn_softcap)
        mask = jnp.ones((B, 1, 1, Tq, block_kv), dtype=bool)
        pb = posb[:, None, None, None, :]
        qp = q_positions[:, None, None, :, None]
        if causal:
            mask &= pb <= qp
        if window is not None:
            mask &= pb > qp - window
        if kv_valid_len is not None:
            mask &= pb < kv_valid_len[:, None, None, None, None]
        mask &= pb < 2**30  # padding sentinel
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None]) * mask
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bKgqk,bkKd->bKgqd", p, vb.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Tq, d), jnp.float32)
    xs = (kb_all.transpose(1, 0, 2, 3, 4), vb_all.transpose(1, 0, 2, 3, 4),
          pos_all.transpose(1, 0, 2))
    if k_scale is not None:
        xs = xs + (ks_all, vs_all)
    (m, l, acc), _ = jax.lax.scan(flash_vmem_body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # [B, KV, G, Tq, d]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, d)
    if _return_lse:
        lse = m + jnp.log(jnp.maximum(l, 1e-30))          # [B, KV, G, Tq]
        return out.astype(q.dtype), lse
    return out.astype(q.dtype)


# -------------------------------------- flash attention with kernel bwd ----
# §Perf optimization: differentiating through the jnp flash scan makes JAX
# stack per-block residuals (measured ~3.3 TB global on qwen3 train_4k —
# EXPERIMENTS.md §Perf). The kernel-style backward saves only (o, lse) and
# recomputes logits per block — exactly what the Pallas flash bwd does.
NO_WINDOW_STATIC = 2**30


def flash_attention_ckpt(q, k, v, q_positions, kv_positions, kv_valid_len, *,
                         scale, causal=True, window=None, attn_softcap=None,
                         block_kv=512):
    """flash_attention with a custom recompute-based backward."""
    win = window if window is not None else NO_WINDOW_STATIC
    return _flash_ckpt(q, k, v, q_positions, kv_positions,
                       kv_valid_len if kv_valid_len is not None
                       else jnp.full((q.shape[0],), 2**30, jnp.int32),
                       jnp.asarray(win, jnp.int32),
                       scale, causal,
                       attn_softcap if attn_softcap is not None else 0.0,
                       block_kv)


@partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def _flash_ckpt(q, k, v, q_pos, kv_pos, kv_len, window, scale, causal,
                softcap, block_kv):
    o, _ = _flash_ckpt_fwd(q, k, v, q_pos, kv_pos, kv_len, window, scale,
                           causal, softcap, block_kv)
    return o


def _flash_ckpt_fwd(q, k, v, q_pos, kv_pos, kv_len, window, scale, causal,
                    softcap, block_kv):
    sc = None if softcap == 0.0 else softcap
    out, lse = flash_attention(
        q, k, v, q_positions=q_pos, kv_positions=kv_pos, kv_valid_len=kv_len,
        scale=scale, causal=causal, window_per_layer=window,
        attn_softcap=sc, block_kv=block_kv, _return_lse=True)
    return out, (q, k, v, q_pos, kv_pos, kv_len, window, out, lse)


def _flash_ckpt_bwd(scale, causal, softcap, block_kv, res, do):
    q, k, v, q_pos, kv_pos, kv_len, window, out, lse = res
    B, Tq, H, d = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    sc = None if softcap == 0.0 else softcap

    pad = (-Tk) % block_kv
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos_p = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
    else:
        kp, vp, kv_pos_p = k, v, kv_pos
    nblk = (Tk + pad) // block_kv

    qg = q.reshape(B, Tq, KV, G, d).astype(jnp.float32) * scale
    og = out.reshape(B, Tq, KV, G, d).astype(jnp.float32)
    dog = do.reshape(B, Tq, KV, G, d).astype(jnp.float32)
    lseg = lse                                             # [B, KV, G, Tq]
    delta = (og * dog).sum(-1).transpose(0, 2, 3, 1)       # [B, KV, G, Tq]
    kb_all = kp.reshape(B, nblk, block_kv, KV, d).transpose(1, 0, 2, 3, 4)
    vb_all = vp.reshape(B, nblk, block_kv, KV, d).transpose(1, 0, 2, 3, 4)
    pos_all = kv_pos_p.reshape(B, nblk, block_kv).transpose(1, 0, 2)

    def flashbwd_vmem_body(dq_acc, xs):
        kb, vb, posb = xs
        logits = jnp.einsum("bqKgd,bkKd->bKgqk", qg, kb.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        dcap = 1.0
        if sc is not None:
            t = jnp.tanh(logits / sc)
            logits_c = t * sc
            dcap = 1.0 - jnp.square(t)
        else:
            logits_c = logits
        mask = jnp.ones((B, 1, 1, Tq, block_kv), dtype=bool)
        pb = posb[:, None, None, None, :]
        qp = q_pos[:, None, None, :, None]
        if causal:
            mask &= pb <= qp
        mask &= pb > qp - window
        mask &= pb < kv_len[:, None, None, None, None]
        mask &= pb < 2**30
        p = jnp.where(mask, jnp.exp(logits_c - lseg[..., None]), 0.0)
        dp = jnp.einsum("bqKgd,bkKd->bKgqk", dog, vb.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * dcap            # [B,KV,G,Tq,blk]
        dq_blk = jnp.einsum("bKgqk,bkKd->bqKgd", ds, kb.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bKgqk,bqKgd->bkKd", ds, qg,
                            preferred_element_type=jnp.float32)
        dv_blk = jnp.einsum("bKgqk,bqKgd->bkKd", p, dog,
                            preferred_element_type=jnp.float32)
        return dq_acc + dq_blk, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, Tq, KV, G, d), jnp.float32)
    dq, (dk_s, dv_s) = jax.lax.scan(flashbwd_vmem_body, dq0,
                                    (kb_all, vb_all, pos_all))
    dq = (dq * scale).reshape(B, Tq, H, d).astype(q.dtype)
    dk = dk_s.transpose(1, 0, 2, 3, 4).reshape(B, Tk + pad, KV, d)[:, :Tk]
    dv = dv_s.transpose(1, 0, 2, 3, 4).reshape(B, Tk + pad, KV, d)[:, :Tk]
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return (dq, dk.astype(k.dtype), dv.astype(v.dtype),
            f0(q_pos), f0(kv_pos), f0(kv_len), f0(window))


_flash_ckpt.defvjp(_flash_ckpt_fwd, _flash_ckpt_bwd)


# ----------------------------------------------- paged attention (ref) -----
def gather_pages(pages, block_table):
    """pages [N, ps, KV, d], block_table [B, Pmax] -> [B, Pmax*ps, KV, d]."""
    B, Pmax = block_table.shape
    ps = pages.shape[1]
    g = pages[block_table]                                 # [B, Pmax, ps, KV, d]
    return g.reshape(B, Pmax * ps, *pages.shape[2:])


def paged_attention_ref(
    q,                 # [B, Tq, H, d] (Tq=1 decode, Tq=chunk prefill)
    k_pages, v_pages,  # [N, ps, KV, d]
    block_table,       # [B, Pmax] int32 (local page indices)
    kv_lens,           # [B] valid kv length (incl. freshly written tokens)
    q_positions,       # [B, Tq]
    *,
    scale, window=None, attn_softcap=None, block_kv=512,
):
    """Reference paged attention: gather pages then flash over them.

    Used as the CPU/dry-run implementation and as the oracle for the
    Pallas kernels.
    """
    B, Pmax = block_table.shape
    ps = k_pages.shape[1]
    k = gather_pages(k_pages, block_table)
    v = gather_pages(v_pages, block_table)
    kv_pos = jnp.broadcast_to(jnp.arange(Pmax * ps, dtype=jnp.int32)[None], (B, Pmax * ps))
    return flash_attention(
        q, k, v, q_positions=q_positions, kv_positions=kv_pos,
        kv_valid_len=kv_lens, scale=scale, causal=True, window=window,
        attn_softcap=attn_softcap, block_kv=min(block_kv, Pmax * ps),
    )


# ------------------------------------------------------------------ mlp ----
def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


def mlp_apply(p, x, act: str):
    """x [..., D]. Gated (SwiGLU/GeGLU) or classic 2-matrix MLP."""
    if act == "gelu_mlp":
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w_in"]), approximate=True)
        return jnp.einsum("...f,fd->...d", h, p["w_out"])
    g = act_fn(act)(jnp.einsum("...d,df->...f", x, p["w_gate"]))
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", g * u, p["w_down"])


# ----------------------------------------------------------------- init ----
def dense_init(key, shape, in_axis_size, dtype, scale=1.0):
    std = scale / math.sqrt(in_axis_size)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def mlp_init(key, d_model, d_ff, act, dtype, n_layers_scale=1.0, stack=()):
    ks = jax.random.split(key, 3)
    s = tuple(stack)
    if act == "gelu_mlp":
        return {
            "w_in": dense_init(ks[0], s + (d_model, d_ff), d_model, dtype),
            "w_out": dense_init(ks[1], s + (d_ff, d_model), d_ff, dtype, n_layers_scale),
        }
    return {
        "w_gate": dense_init(ks[0], s + (d_model, d_ff), d_model, dtype),
        "w_up": dense_init(ks[1], s + (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(ks[2], s + (d_ff, d_model), d_ff, dtype, n_layers_scale),
    }
