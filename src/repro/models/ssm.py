"""State-space primitives: Mamba2 (SSD) and RWKV-6 (Finch) blocks.

Both are implemented twice:
  * chunkwise-parallel form (prefill / training) — matmul-rich, the
    compute-bound "prompt phase" of these architectures;
  * recurrent form (decode) — O(1) state update, the bandwidth-bound
    "token phase".
The phase asymmetry the paper exploits therefore exists for SSMs too,
and the Splitwiser mixed step applies (see models/rwkv.py:mixed).

All decay exponentials are evaluated as exp(ΔlogP) with ΔlogP <= 0, so the
chunkwise forms are numerically safe for any chunk length.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, rms_norm


# ===================================================================== Mamba2
# Projections are stored SEPARATELY (z/x/B/C/dt) rather than as one fused
# in_proj: slicing a fused output dim at non-shard boundaries would force
# GSPMD to reshard; separate tensors give clean Megatron-style TP (z/x
# sharded on d_inner, B/C/dt small & replicated, out_proj contracts the
# sharded dim -> one all-reduce).
def mamba2_init(key, cfg, dtype, stack=()):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    H = cfg.ssm_heads
    N = cfg.ssm_state
    W = cfg.ssm_conv_width
    ks = iter(jax.random.split(key, 10))
    s = tuple(stack)
    return {
        "ln": jnp.zeros(s + (D,), dtype),
        "wz": dense_init(next(ks), s + (D, d_in), D, dtype),
        "wx": dense_init(next(ks), s + (D, d_in), D, dtype),
        "wB": dense_init(next(ks), s + (D, N), D, dtype),
        "wC": dense_init(next(ks), s + (D, N), D, dtype),
        "wdt": dense_init(next(ks), s + (D, H), D, dtype),
        "conv_x": dense_init(next(ks), s + (W, d_in), W, dtype),
        "conv_B": dense_init(next(ks), s + (W, N), W, dtype),
        "conv_C": dense_init(next(ks), s + (W, N), W, dtype),
        "conv_b_x": jnp.zeros(s + (d_in,), dtype),
        "conv_b_B": jnp.zeros(s + (N,), dtype),
        "conv_b_C": jnp.zeros(s + (N,), dtype),
        "A_log": jnp.broadcast_to(jnp.log(jnp.linspace(1.0, 16.0, H)), s + (H,)).astype(dtype),
        "D_skip": jnp.ones(s + (H,), dtype),
        "dt_bias": jnp.broadcast_to(jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, H))), s + (H,)).astype(dtype),
        "norm": jnp.zeros(s + (d_in,), dtype),
        "out_proj": dense_init(next(ks), s + (d_in, D), d_in, dtype),
    }


def _mamba_proj(lp, x, cfg):
    """x [B,T,D] -> (z [B,T,d_in], x/B/C projections, dt [B,T,H])."""
    z = jnp.einsum("btd,de->bte", x, lp["wz"])
    xc = jnp.einsum("btd,de->bte", x, lp["wx"])
    Bc = jnp.einsum("btd,dn->btn", x, lp["wB"])
    Cc = jnp.einsum("btd,dn->btn", x, lp["wC"])
    dt = jnp.einsum("btd,dh->bth", x, lp["wdt"])
    return z, (xc, Bc, Cc), dt


def _causal_conv(xbc, conv_state, w, b):
    """Depthwise causal conv. xbc [B,T,Cc]; conv_state [B,W-1,Cc] history.

    Returns (y [B,T,Cc], new_state [B,W-1,Cc]).
    """
    W = w.shape[0]
    full = jnp.concatenate([conv_state, xbc], axis=1)          # [B, T+W-1, Cc]
    # y_t = sum_j w[j] * full[t+j]
    T = xbc.shape[1]
    y = sum(full[:, j : j + T] * w[j] for j in range(W)) + b
    new_state = full[:, -(W - 1):] if W > 1 else conv_state
    return jax.nn.silu(y), new_state


def mamba2_chunk_scan(xh, Bc, Cc, la, h0, chunk=64):
    """SSD chunkwise scan.

    xh [B,T,H,P] (already dt-scaled inputs dt_t*x_t), Bc/Cc [B,T,N],
    la [B,T,H] log-decay (<=0), h0 [B,H,P,N].
    Returns (y [B,T,H,P], h_out).
    """
    B, T, H, Pd = xh.shape
    N = Bc.shape[-1]
    pad = (-T) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
    nchunk = (T + pad) // chunk
    rs = lambda t: t.reshape(B, nchunk, chunk, *t.shape[2:]).swapaxes(0, 1)
    xh_c, B_c, C_c, la_c = rs(xh), rs(Bc), rs(Cc), rs(la)

    def ssd_vmem_body(h, xs):
        xq, bq, cq, laq = xs                    # [B,Q,H,P], [B,Q,N], [B,Q,H]
        laq = laq.astype(jnp.float32)
        L = jnp.cumsum(laq, axis=1)             # [B,Q,H]
        # intra-chunk: y[t] += sum_{i<=t} exp(L_t - L_i) (C_t.B_i) xq_i
        M = jnp.exp(L[:, :, None, :] - L[:, None, :, :])       # [B,Q(t),Q(i),H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        M = jnp.where(tri[None, :, :, None], M, 0.0)
        G = jnp.einsum("btn,bin->bti", cq, bq)                 # [B,Q,Q]
        y = jnp.einsum("bti,btih,bihp->bthp", G.astype(jnp.float32),
                       M, xq.astype(jnp.float32))
        # inter-chunk: y[t] += exp(L_t) C_t . h0
        y = y + jnp.einsum("btn,bhpn->bthp", cq.astype(jnp.float32),
                           h.astype(jnp.float32)) * jnp.exp(L)[:, :, :, None]
        # state: h_out = exp(L_last) h0 + sum_i exp(L_last - L_i) xq_i B_i^T
        Llast = L[:, -1]                                       # [B,H]
        decay_i = jnp.exp(Llast[:, None, :] - L)               # [B,Q,H]
        h_new = jnp.exp(Llast)[:, :, None, None] * h.astype(jnp.float32) + jnp.einsum(
            "bihp,bin,bih->bhpn", xq.astype(jnp.float32), bq.astype(jnp.float32), decay_i)
        return h_new, y

    h_out, ys = jax.lax.scan(ssd_vmem_body, h0.astype(jnp.float32),
                             (xh_c, B_c, C_c, la_c))
    y = ys.swapaxes(0, 1).reshape(B, T + pad, H, Pd)[:, :T]
    return y.astype(xh.dtype), h_out


def mamba2_block(lp, cfg, x, conv_state, ssm_state, chunk=64):
    """Full-sequence (chunked) Mamba2 block. x [B,T,D].

    conv_state: dict(x [B,W-1,d_in], B [B,W-1,N], C [B,W-1,N]).
    Returns (y [B,T,D], new_conv_state, new_ssm_state).
    """
    H = cfg.ssm_heads
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    z, (xc, Bc, Cc), dt = _mamba_proj(lp, h, cfg)
    xc, sx = _causal_conv(xc, conv_state["x"], lp["conv_x"], lp["conv_b_x"])
    Bc, sB = _causal_conv(Bc, conv_state["B"], lp["conv_B"], lp["conv_b_B"])
    Cc, sC = _causal_conv(Cc, conv_state["C"], lp["conv_C"], lp["conv_b_C"])
    xh = xc.reshape(*xc.shape[:-1], H, -1)                     # [B,T,H,P]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    la = -dt * jnp.exp(lp["A_log"].astype(jnp.float32))        # [B,T,H] <= 0
    y, ssm_state = mamba2_chunk_scan(xh * dt[..., None].astype(xh.dtype),
                                     Bc, Cc, la, ssm_state, chunk)
    y = y + xh * lp["D_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:-1], -1)                           # [B,T,d_in]
    y = rms_norm(y * jax.nn.silu(z), lp["norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, lp["out_proj"])
    return x + out, {"x": sx, "B": sB, "C": sC}, ssm_state


def mamba2_decode(lp, cfg, x, conv_state, ssm_state):
    """One-token recurrent Mamba2 step. x [B,D].

    Returns (y [B,D], new_conv_state, new_ssm_state).
    """
    H = cfg.ssm_heads
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    z, (xc, Bc, Cc), dt = _mamba_proj(lp, h[:, None], cfg)

    def conv1(t, s, w, b):
        full = jnp.concatenate([s, t], axis=1)                 # [B,W,C]
        y = jax.nn.silu(jnp.einsum("bwc,wc->bc", full, w) + b)
        return y, full[:, 1:]

    xc, sx = conv1(xc, conv_state["x"], lp["conv_x"], lp["conv_b_x"])
    Bc, sB = conv1(Bc, conv_state["B"], lp["conv_B"], lp["conv_b_B"])
    Cc, sC = conv1(Cc, conv_state["C"], lp["conv_C"], lp["conv_b_C"])
    z, dt = z[:, 0], dt[:, 0]
    xh = xc.reshape(x.shape[0], H, -1)                         # [B,H,P]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-dt * jnp.exp(lp["A_log"].astype(jnp.float32)))  # [B,H]
    xdt = xh.astype(jnp.float32) * dt[..., None]
    h_new = a[..., None, None] * ssm_state.astype(jnp.float32) + jnp.einsum(
        "bhp,bn->bhpn", xdt, Bc.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cc.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * lp["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(x.shape[0], -1).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), lp["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, lp["out_proj"])
    return x + out, {"x": sx, "B": sB, "C": sC}, h_new.astype(ssm_state.dtype)


def mamba2_state_shapes(cfg, batch):
    """(conv_state shape dict, ssm_state shape)."""
    d_in = cfg.ssm_expand * cfg.d_model
    Pd = d_in // cfg.ssm_heads
    W = cfg.ssm_conv_width
    conv = {"x": (batch, W - 1, d_in), "B": (batch, W - 1, cfg.ssm_state),
            "C": (batch, W - 1, cfg.ssm_state)}
    return conv, (batch, cfg.ssm_heads, Pd, cfg.ssm_state)


# ====================================================================== RWKV6
LORA_R = 32


def rwkv6_init(key, cfg, dtype, stack=()):
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    F = cfg.d_ff
    ks = iter(jax.random.split(key, 16))
    s = tuple(stack)
    L = cfg.n_layers
    out_scale = 1.0 / math.sqrt(2 * L)
    tmix = {
        "mu_r": jnp.full(s + (D,), 0.5, dtype), "mu_k": jnp.full(s + (D,), 0.5, dtype),
        "mu_v": jnp.full(s + (D,), 0.5, dtype), "mu_g": jnp.full(s + (D,), 0.5, dtype),
        "mu_w": jnp.full(s + (D,), 0.5, dtype),
        "w0": jnp.full(s + (D,), -2.0, dtype),
        "w_a": dense_init(next(ks), s + (D, LORA_R), D, dtype),
        "w_b": dense_init(next(ks), s + (LORA_R, D), LORA_R, dtype),
        "wr": dense_init(next(ks), s + (D, D), D, dtype),
        "wk": dense_init(next(ks), s + (D, D), D, dtype),
        "wv": dense_init(next(ks), s + (D, D), D, dtype),
        "wg": dense_init(next(ks), s + (D, D), D, dtype),
        "wo": dense_init(next(ks), s + (D, D), D, dtype, out_scale),
        "u": jnp.zeros(s + (H, hd), dtype),
        "ln_x": jnp.zeros(s + (D,), dtype),
    }
    cmix = {
        "mu_r": jnp.full(s + (D,), 0.5, dtype), "mu_k": jnp.full(s + (D,), 0.5, dtype),
        "wr": dense_init(next(ks), s + (D, D), D, dtype),
        "wk": dense_init(next(ks), s + (D, F), D, dtype),
        "wv": dense_init(next(ks), s + (F, D), F, dtype, out_scale),
    }
    return {"ln1": jnp.zeros(s + (D,), dtype), "ln2": jnp.zeros(s + (D,), dtype),
            "tmix": tmix, "cmix": cmix}


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _shifted(x, x_last):
    """x [B,T,D]; x_last [B,D] (token before this span) -> x_{t-1} per t."""
    return jnp.concatenate([x_last[:, None], x[:, :-1]], axis=1)


def rwkv6_wkv_chunk(r, k, v, lw, u, S0, chunk=32):
    """Chunkwise WKV with per-channel data-dependent decay.

    r/k/v [B,T,H,K]; lw [B,T,H,K] log-decay (<=0); u [H,K]; S0 [B,H,K,V].
    Returns (o [B,T,H,V], S_out). All exp args are <= 0 (safe).
    """
    B, T, H, K = r.shape
    pad = (-T) % chunk
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v, lw = (jnp.pad(t, z4) for t in (r, k, v, lw))
    nchunk = (T + pad) // chunk
    rs = lambda t: t.reshape(B, nchunk, chunk, H, K).swapaxes(0, 1)
    rc, kc, vc, lwc = rs(r), rs(k), rs(v), rs(lw)

    def wkv_vmem_body(S, xs):
        rq, kq, vq, lq = (t.astype(jnp.float32) for t in xs)   # [B,Q,H,K]
        L = jnp.cumsum(lq, axis=1)                             # [B,Q,H,K]
        Lm1 = jnp.concatenate([jnp.zeros_like(L[:, :1]), L[:, :-1]], axis=1)
        # intra: A[t,i] = sum_c r_t k_i exp(Lm1_t - L_i), i < t; diag: r.(u*k)
        diff = Lm1[:, :, None] - L[:, None]                    # [B,Q(t),Q(i),H,K]
        Q = rq.shape[1]
        tri = jnp.tril(jnp.ones((Q, Q), bool), -1)
        E = jnp.where(tri[None, :, :, None, None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        A = jnp.einsum("bthk,bihk,btihk->bthi", rq, kq, E)
        A_diag = jnp.einsum("bthk,hk,bthk->bth", rq, u.astype(jnp.float32), kq)
        o = jnp.einsum("bthi,bihv->bthv", A, vq)
        o = o + A_diag[..., None] * vq
        # inter: o_t += (r_t * exp(Lm1_t)) @ S
        o = o + jnp.einsum("bthk,bhkv->bthv", rq * jnp.exp(Lm1), S)
        # state: S' = diag(exp(L_last)) S + sum_i exp(L_last - L_i) k_i v_i
        Llast = L[:, -1]                                       # [B,H,K]
        S_new = jnp.exp(Llast)[..., None] * S + jnp.einsum(
            "bihk,bihv->bhkv", kq * jnp.exp(Llast[:, None] - L), vq)
        return S_new, o

    S_out, os = jax.lax.scan(wkv_vmem_body, S0.astype(jnp.float32),
                             (rc, kc, vc, lwc))
    o = os.swapaxes(0, 1).reshape(B, T + pad, H, -1)[:, :T]
    return o, S_out


def rwkv6_tmix(lp, cfg, x, x_last, S0, chunk=32):
    """Time-mix over a span. x [B,T,D]; x_last [B,D]; S0 [B,H,K,V].

    Returns (out [B,T,D], new_x_last [B,D], S_out).
    """
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    xp = _shifted(x, x_last)
    rx = _lerp(x, xp, lp["mu_r"]); kx = _lerp(x, xp, lp["mu_k"])
    vx = _lerp(x, xp, lp["mu_v"]); gx = _lerp(x, xp, lp["mu_g"])
    wx = _lerp(x, xp, lp["mu_w"])
    shp = (*x.shape[:-1], H, hd)
    r = jnp.einsum("btd,de->bte", rx, lp["wr"]).reshape(shp)
    k = jnp.einsum("btd,de->bte", kx, lp["wk"]).reshape(shp)
    v = jnp.einsum("btd,de->bte", vx, lp["wv"]).reshape(shp)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", gx, lp["wg"]))
    # data-dependent per-channel decay (the Finch hallmark)
    dw = jnp.einsum("btr,rd->btd", jnp.tanh(jnp.einsum("btd,dr->btr", wx, lp["w_a"])), lp["w_b"])
    lw = -jnp.exp((lp["w0"] + dw).astype(jnp.float32)).reshape(shp[:-2] + (H, hd))
    o, S_out = rwkv6_wkv_chunk(r, k, v, lw, lp["u"], S0, chunk)
    # per-head RMS norm (RWKV's GroupNorm over heads) — TP-local on the
    # sharded head dim
    from repro.models.layers import head_rms_norm
    o = head_rms_norm(o.astype(x.dtype), lp["ln_x"].reshape(H, hd), cfg.norm_eps)
    o = o.reshape(*x.shape[:-1], D) * g.astype(x.dtype)
    out = jnp.einsum("btd,de->bte", o, lp["wo"])
    return out, x[:, -1], S_out


def rwkv6_cmix(lp, cfg, x, x_last):
    """Channel-mix. Returns (out [B,T,D], new_x_last)."""
    xp = _shifted(x, x_last)
    rx = _lerp(x, xp, lp["mu_r"]); kx = _lerp(x, xp, lp["mu_k"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", rx, lp["wr"]))
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", kx, lp["wk"])))
    return r * jnp.einsum("btf,fd->btd", kk, lp["wv"]), x[:, -1]


def rwkv6_layer(lp, cfg, x, state, chunk=32):
    """One RWKV6 layer over a span. state = dict(x_tm, x_cm [B,D], S [B,H,K,V])."""
    o, x_tm, S = rwkv6_tmix(lp["tmix"], cfg, rms_norm(x, lp["ln1"], cfg.norm_eps),
                            state["x_tm"], state["S"], chunk)
    x = x + o
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    o2, x_cm = rwkv6_cmix(lp["cmix"], cfg, h, state["x_cm"])
    x = x + o2
    return x, {"x_tm": x_tm, "x_cm": x_cm, "S": S}


def rwkv6_state_shapes(cfg, batch):
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    return {"x_tm": (batch, D), "x_cm": (batch, D), "S": (batch, H, hd, hd)}
