"""RWKV6 language model (attention-free; recurrent state instead of KV).

Serving phases still exist: prefill = chunkwise-parallel scan (compute
bound), decode = recurrent step (bandwidth bound: reads the full state +
weights per token), so the Splitwiser engine drives this arch through the
same phase-split scheduler with state-slot caches instead of KV pages.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.layers import rms_norm
from repro.models.sharding import constrain
from repro.models.transformer import pad_vocab, unembed


def init_params(cfg, key, dtype=jnp.float32, tp: int = 1):
    del tp  # no attention heads to pad
    Vp = pad_vocab(cfg.vocab_size)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": (jax.random.normal(k1, (Vp, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "ln0": jnp.zeros((cfg.d_model,), dtype),
        "blocks": ssm.rwkv6_init(k2, cfg, dtype, stack=(cfg.n_layers,)),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "head": (jax.random.normal(k3, (Vp, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
    }


def init_state(cfg, batch, dtype=jnp.float32):
    shapes = ssm.rwkv6_state_shapes(cfg, batch)
    L = cfg.n_layers
    return {k: jnp.zeros((L,) + v, dtype) for k, v in shapes.items()}


def forward(params, cfg, tokens, state, *, chunk=32, policy=None,
            return_all=False, remat=False):
    """tokens [B, T]; state stacked [L, ...]. Returns (logits, new_state)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = rms_norm(x, params["ln0"], cfg.norm_eps)
    if policy is not None:
        x = constrain(x, policy, "batch", "seq", None)

    def body(xc, st):
        lp, s = st
        xc, s2 = ssm.rwkv6_layer(lp, cfg, xc, s, chunk=chunk)
        if policy is not None:
            xc = constrain(xc, policy, "batch", "seq", None)
        return xc, s2

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if not return_all:
        x = x[:, -1]
    logits = unembed(params, cfg, x, policy)
    return logits, new_state


def train_logits(params, cfg, batch, *, tp=1, policy=None, moe_fn=None,
                 remat=False, chunk=32):
    del tp, moe_fn
    state = init_state(cfg, batch["tokens"].shape[0],
                       jax.tree.leaves(params)[0].dtype)
    logits, _ = forward(params, cfg, batch["tokens"], state, chunk=chunk,
                        policy=policy, return_all=True, remat=remat)
    return logits, jnp.float32(0.0)


def prefill(params, cfg, tokens, *, tp=1, policy=None, chunk=32, state=None):
    """Returns (last_logits [B, Vp], state)."""
    del tp
    if state is None:
        state = init_state(cfg, tokens.shape[0], jax.tree.leaves(params)[0].dtype)
    return forward(params, cfg, tokens, state, chunk=chunk, policy=policy)


def decode(params, cfg, tokens, state, *, tp=1, policy=None):
    """tokens [B] -> (logits [B, Vp], state). One recurrent step."""
    del tp
    logits, st = forward(params, cfg, tokens[:, None], state, chunk=1,
                         policy=policy)
    return logits, st


def mixed(params, cfg, mb, p_state, d_state, *, tp=1, policy=None):
    """Splitwiser step for the state-cache family.

    Prefill chunks and decode tokens run in one jitted program (phase
    co-residency); the projection GEMMs are not merged across phases for
    SSMs (sequence-structure ops separate the phases before the GEMMs;
    see models/ssm.py).
    mb: p_tokens [P, C], p_lens [P]; d_tokens [B], d_active [B].
    """
    del tp
    p_logits, p_state = forward(params, cfg, mb["p_tokens"], p_state,
                                chunk=min(32, mb["p_tokens"].shape[1]),
                                policy=policy)
    d_logits, d_state = forward(params, cfg, mb["d_tokens"][:, None], d_state,
                                chunk=1, policy=policy)
    return p_logits, d_logits, p_state, d_state
