"""Decoder-only transformer (dense / MoE / VLM families).

Provides init plus the four step flavors the system needs:
  - train_hidden / train_logits       (full-sequence causal)
  - prefill                           (batched prompt -> KV + last logits)
  - decode                            (one token/seq over paged KV)
  - mixed                             (Splitwiser: prefill chunks + decode
                                       tokens fused in ONE program, sharing
                                       every GEMM)

All functions are pure and `jax.eval_shape`-able (the multi-pod dry-run
lowers them from ShapeDtypeStructs without allocating).

GQA/TP head padding: when kv heads don't divide the tensor-parallel axis,
q/kv heads are padded *at apply time* (and in the wq/wo storage layout)
while wk/wv keep the real architecture's parameters; padded q heads are
masked before the output projection so they are exactly inert (zero
gradient, zero contribution). See `gqa_layout`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.layers import (
    NEG_INF, dense_init, flash_attention, flash_attention_ckpt,
    head_rms_norm, mlp_apply, mlp_init, paged_attention_ref, rms_norm, rope,
    softcap, act_fn,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.sharding import constrain

VOCAB_PAD = 256
NO_WINDOW = 2**30


def pad_vocab(v: int) -> int:
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


# ------------------------------------------------------------ GQA layout ---
def gqa_layout(H: int, KV: int, tp: int = 1):
    """Padded head layout for tensor parallelism.

    Returns (H_p, KV_p, q_map, kv_map, head_mask):
      q_map [H_p]   -> real q head feeding padded slot (-1 = inert pad)
      kv_map [KV_p] -> real kv head replicated into padded kv slot
      head_mask [H_p] float 0/1 (applied to attention output)
    Padded groups are uniform: padded q slot j uses padded kv slot j // G_p.
    """
    if KV % tp == 0:
        KV_p = KV
    else:
        if not KV < tp:
            raise ValueError(
                f"KV heads ({KV}) must divide or be smaller than tp={tp} "
                f"to replicate into padded slots (H={H})")
        KV_p = tp * math.ceil(KV / tp)
        if KV_p % KV:
            raise ValueError(
                f"padded KV heads {KV_p} not a multiple of KV={KV} (tp={tp})")
    R = KV_p // KV
    if H % KV:
        raise ValueError(f"query heads H={H} must be a multiple of KV={KV}")
    G = H // KV
    G_p = math.ceil(G / R)
    H_p = KV_p * G_p
    q_map = np.full(H_p, -1, np.int32)
    for r in range(KV):
        for i in range(R):
            for t in range(G_p):
                src = i * G_p + t
                if src < G:
                    q_map[(r * R + i) * G_p + t] = r * G + src
    kv_map = (np.arange(KV_p) // R).astype(np.int32)
    head_mask = (q_map >= 0).astype(np.float32)
    return H_p, KV_p, q_map, kv_map, head_mask


def layer_windows(cfg) -> np.ndarray:
    """Per-layer attention window (NO_WINDOW = global) as a scan input."""
    L = cfg.n_layers
    if cfg.local_global_pattern and cfg.sliding_window:
        pat = cfg.local_global_pattern
        return np.array(
            [cfg.sliding_window if pat[i % len(pat)] == "local" else NO_WINDOW
             for i in range(L)], np.int32)
    return np.full(L, NO_WINDOW, np.int32)


# ----------------------------------------------------------------- init ----
def init_params(cfg, key, dtype=jnp.float32, tp: int = 1):
    D, hd, L = cfg.d_model, cfg.head_dim, cfg.n_layers
    H_p, KV_p, q_map, _, _ = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    Vp = pad_vocab(cfg.vocab_size)
    keys = iter(jax.random.split(key, 24))
    out_scale = 1.0 / math.sqrt(2 * L)

    # wq stored in the padded layout (pad columns zero & inert); wk/wv real.
    wq = dense_init(next(keys), (L, D, H_p, hd), D, dtype)
    wq = wq * jnp.asarray(q_map >= 0, dtype)[None, None, :, None]
    wo = dense_init(next(keys), (L, H_p, hd, D), H_p * hd, dtype, out_scale)

    blocks = {
        "ln1": jnp.zeros((L, D), dtype),
        "ln2": jnp.zeros((L, D), dtype),
        "wq": wq,
        "wk": dense_init(next(keys), (L, D, cfg.n_kv_heads, hd), D, dtype),
        "wv": dense_init(next(keys), (L, D, cfg.n_kv_heads, hd), D, dtype),
        "wo": wo,
    }
    if cfg.use_qk_norm:
        blocks["q_norm"] = jnp.zeros((L, hd), dtype)
        blocks["k_norm"] = jnp.zeros((L, hd), dtype)
    if cfg.post_attn_norm:
        blocks["ln1b"] = jnp.zeros((L, D), dtype)
        blocks["ln2b"] = jnp.zeros((L, D), dtype)
    if cfg.is_moe:
        blocks["moe"] = moe_init(next(keys), cfg, dtype, stack=(L,))
    else:
        blocks["mlp"] = mlp_init(next(keys), D, cfg.d_ff, cfg.mlp_act, dtype,
                                 out_scale, stack=(L,))
    params = {
        "embed": (jax.random.normal(next(keys), (Vp, D), jnp.float32) * 0.02).astype(dtype),
        "ln_f": jnp.zeros((D,), dtype),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(next(keys), (Vp, D), jnp.float32) * 0.02).astype(dtype)
    if cfg.family == "vlm":
        params["proj"] = {
            "ln": jnp.zeros((cfg.d_vision,), dtype),
            "w1": dense_init(next(keys), (cfg.d_vision, D), cfg.d_vision, dtype),
            "w2": dense_init(next(keys), (D, D), D, dtype),
        }
    return params


# ------------------------------------------------------------- embedding ---
def embed(params, cfg, tokens, policy=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.emb_scale_by_sqrt_dim:
        x = x * math.sqrt(cfg.d_model)
    return x


def vision_prefix(params, cfg, patches):
    """[B, Np, d_vision] precomputed patch embeds -> [B, Np, D] prefix."""
    p = params["proj"]
    h = rms_norm(patches, p["ln"], cfg.norm_eps)
    h = jax.nn.gelu(jnp.einsum("bnd,dD->bnD", h, p["w1"]), approximate=True)
    return jnp.einsum("bnd,dD->bnD", h, p["w2"])


def unembed(params, cfg, x, policy=None):
    table = params["head"] if "head" in params else params["embed"]
    logits = jnp.einsum("...d,vd->...v", x, table, preferred_element_type=jnp.float32)
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    Vp = table.shape[0]
    if Vp != cfg.vocab_size:
        vmask = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(vmask, logits, NEG_INF)
    return logits


# ----------------------------------------------------------- block pieces --
def _qkv(cfg, lay, lp, x):
    """x [..., D] -> q [..., H_p, hd] (padded layout), k/v [..., KV, hd]."""
    q = jnp.einsum("...d,dhk->...hk", x, lp["wq"])
    k = jnp.einsum("...d,dhk->...hk", x, lp["wk"])
    v = jnp.einsum("...d,dhk->...hk", x, lp["wv"])
    if cfg.use_qk_norm:
        q = head_rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, lp["k_norm"], cfg.norm_eps)
    return q, k, v


def _expand_kv(t, kv_map, policy=None, names=()):
    if len(kv_map) == t.shape[-2] and bool(np.all(kv_map == np.arange(len(kv_map)))):
        out = t                                   # identity (no TP padding)
    else:
        out = jnp.take(t, jnp.asarray(kv_map), axis=-2)
    if policy is not None and names:
        out = constrain(out, policy, *names)
    return out


def _attn_scale(cfg):
    return cfg.attn_scale_override or (1.0 / math.sqrt(cfg.head_dim))


def _o_proj(cfg, lp, o, head_mask):
    o = o * jnp.asarray(head_mask, o.dtype)[..., :, None]
    return jnp.einsum("...hk,hkd->...d", o, lp["wo"])


def _ffn(cfg, lp, x2d, moe_fn):
    """x2d [T, D] -> (y2d, aux)."""
    if cfg.is_moe:
        return moe_fn(lp["moe"], x2d)
    return mlp_apply(lp["mlp"], x2d, cfg.mlp_act), jnp.float32(0.0)


def default_moe_fn(cfg):
    gate_act = act_fn("silu" if cfg.mlp_act == "silu" else "gelu")
    def fn(lp, x2d):
        return moe_apply(lp, x2d, n_experts=cfg.n_experts, top_k=cfg.top_k,
                         act=gate_act,
                         capacity_factor=cfg.moe_capacity_factor)
    return fn


# ------------------------------------------------------- full-seq forward --
def _seq_block(cfg, lay, lp, window, x, positions, *, policy, moe_fn,
               collect_kv=False, kv_fake_quant=None):
    """One layer on a full sequence. x [B, T, D]; positions [B, T].

    kv_fake_quant: optional quantize-dequantize applied to K/V at the
    ATTENTION input only (collected KV stays fp, commit re-quantizes to
    the identical codes — q8 is idempotent).  The int8 serving path uses
    it so monolithic prefill attends to exactly the values the chunked
    paths re-read from quantized pages; see ``kernels/kv_int8``.
    """
    H_p, KV_p, _, kv_map, head_mask = lay
    B, T, D = x.shape
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lay, lp, h)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    ke = _expand_kv(k, kv_map, policy, ("batch", "seq", "kv_heads", None))
    ve = _expand_kv(v, kv_map, policy, ("batch", "seq", "kv_heads", None))
    ka = ke if kv_fake_quant is None else kv_fake_quant(ke)
    va = ve if kv_fake_quant is None else kv_fake_quant(ve)
    # custom recompute-based backward (kernel-style; §Perf)
    o = flash_attention_ckpt(
        q, ka, va, positions, positions, None,
        scale=_attn_scale(cfg), causal=True, window=window,
        attn_softcap=cfg.attn_logit_softcap)
    attn_out = _o_proj(cfg, lp, o, head_mask)
    if cfg.post_attn_norm:
        attn_out = rms_norm(attn_out, lp["ln1b"], cfg.norm_eps)
    x = x + attn_out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    y2d, aux = _ffn(cfg, lp, h2.reshape(B * T, D), moe_fn)
    y = y2d.reshape(B, T, D)
    if cfg.post_attn_norm:
        y = rms_norm(y, lp["ln2b"], cfg.norm_eps)
    x = x + y
    if policy is not None:
        x = constrain(x, policy, "batch", "seq", None)
    kv_out = (ke, ve) if collect_kv else None
    return x, aux, kv_out


def forward_hidden(params, cfg, x, positions, *, tp=1, policy=None,
                   moe_fn=None, remat=False, collect_kv=False,
                   kv_fake_quant=None):
    """Scan the layer stack. Returns (hidden [B,T,D], aux, kv or None)."""
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    moe_fn = moe_fn or (default_moe_fn(cfg) if cfg.is_moe else None)
    windows = jnp.asarray(layer_windows(cfg))

    def body(carry, xs):
        xc, aux = carry
        lp, win = xs
        xc, a, kv = _seq_block(cfg, lay, lp, win, xc, positions,
                               policy=policy, moe_fn=moe_fn,
                               collect_kv=collect_kv,
                               kv_fake_quant=kv_fake_quant)
        return (xc, aux + a), kv

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (x, aux), kv = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                (params["blocks"], windows))
    return rms_norm(x, params["ln_f"], cfg.norm_eps), aux, kv


def train_hidden(params, cfg, batch, *, tp=1, policy=None, moe_fn=None,
                 remat=False):
    """batch: tokens [B,T] (+ patches for vlm). Returns (hidden, aux)."""
    tokens = batch["tokens"]
    x = embed(params, cfg, tokens, policy)
    if cfg.family == "vlm":
        x = jnp.concatenate([vision_prefix(params, cfg, batch["patches"]), x], axis=1)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    if policy is not None:
        x = constrain(x, policy, "batch", "seq", None)
    hidden, aux, _ = forward_hidden(params, cfg, x, positions, tp=tp,
                                    policy=policy, moe_fn=moe_fn, remat=remat)
    return hidden, aux


def train_logits(params, cfg, batch, **kw):
    hidden, aux = train_hidden(params, cfg, batch, **kw)
    return unembed(params, cfg, hidden), aux


# ----------------------------------------------------------------- prefill -
def prefill(params, cfg, tokens, *, patches=None, tp=1, policy=None,
            moe_fn=None, start_pos=0, kv_fake_quant=None):
    """Full-prompt prefill. tokens [B, S].

    Returns (last_logits [B, Vp], (k, v) each [L, B, S_tot, KV_p, hd]).
    """
    x = embed(params, cfg, tokens, policy)
    if cfg.family == "vlm" and patches is not None:
        x = jnp.concatenate([vision_prefix(params, cfg, patches), x], axis=1)
    B, S, _ = x.shape
    positions = start_pos + jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if policy is not None:
        x = constrain(x, policy, "batch", "seq", None)
    hidden, aux, kv = forward_hidden(params, cfg, x, positions, tp=tp,
                                     policy=policy, moe_fn=moe_fn,
                                     collect_kv=True,
                                     kv_fake_quant=kv_fake_quant)
    last = hidden[:, -1]
    return unembed(params, cfg, last, policy), kv


# ------------------------------------------------------------------ decode -
def write_kv_token(kpg, vpg, k, v, block_table, seq_lens, active=None):
    """Scatter one new token per sequence into the page pool.

    kpg/vpg [N, ps, KV_p, hd]; k/v [B, KV_p, hd]. The last page of the pool
    is the trash page for inactive slots (never allocated by the engine).
    """
    ps = kpg.shape[1]
    pidx = jnp.take_along_axis(block_table, (seq_lens // ps)[:, None], 1)[:, 0]
    off = seq_lens % ps
    if active is not None:
        trash = kpg.shape[0] - 1
        pidx = jnp.where(active, pidx, trash)
    return kpg.at[pidx, off].set(k), vpg.at[pidx, off].set(v)


def default_paged_attn(q, kpg, vpg, block_table, kv_lens, q_positions, *,
                       scale, window, attn_softcap):
    return paged_attention_ref(q, kpg, vpg, block_table, kv_lens, q_positions,
                               scale=scale, window=window,
                               attn_softcap=attn_softcap)


# Pluggable paged write+attend steps. The WRITE lives inside the pluggable
# fn so the production path can run it in a shard_map island (GSPMD cannot
# partition data-dependent page scatters/gathers; see launch/spmd.py).
def default_decode_attn(q, k_new, v_new, kpg, vpg, block_table, seq_lens,
                        active, *, scale, window, attn_softcap):
    """q [B,1,H_p,hd]; k_new/v_new [B,KV_p,hd]. Returns (o, kpg, vpg)."""
    kpg, vpg = write_kv_token(kpg, vpg, k_new, v_new, block_table, seq_lens,
                              active)
    o = paged_attention_ref(q, kpg, vpg, block_table, seq_lens + 1,
                            seq_lens[:, None], scale=scale, window=window,
                            attn_softcap=attn_softcap)
    return o, kpg, vpg


def default_chunk_attn(q, k_new, v_new, kpg, vpg, block_table, start, lens, *,
                       scale, window, attn_softcap):
    """q [P,C,H_p,hd]; k_new/v_new [P,C,KV_p,hd]. Returns (o, kpg, vpg)."""
    kpg, vpg = write_kv_chunk(kpg, vpg, k_new, v_new, block_table, start, lens)
    C = q.shape[1]
    q_pos = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    o = paged_attention_ref(q, kpg, vpg, block_table, start + lens, q_pos,
                            scale=scale, window=window,
                            attn_softcap=attn_softcap)
    return o, kpg, vpg


def decode(params, cfg, tokens, k_pages, v_pages, block_table, seq_lens, *,
           active=None, attn_fn=None, tp=1, policy=None, moe_fn=None):
    """One decode step. tokens [B]; pages [L, N, ps, KV_p, hd].

    attn_fn: a `default_decode_attn`-shaped write+attend step.
    Returns (logits [B, Vp], (k_pages, v_pages)).
    """
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    H_p, KV_p, _, kv_map, head_mask = lay
    attn_fn = attn_fn or default_decode_attn
    moe_fn = moe_fn or (default_moe_fn(cfg) if cfg.is_moe else None)
    windows = jnp.asarray(layer_windows(cfg))

    x = embed(params, cfg, tokens, policy)        # [B, D]
    if policy is not None:
        x = constrain(x, policy, "batch", None)
    B, D = x.shape
    pos = seq_lens                                 # next position == current len
    act = active if active is not None else jnp.ones((B,), bool)

    def body(carry, xs):
        xc, aux = carry
        lp, kpg, vpg, win = xs
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lay, lp, h)            # q [B,H_p,hd]; k/v [B,KV,hd]
        q = rope(q[:, None], pos[:, None], cfg.rope_theta)       # [B,1,H_p,hd]
        k = rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        ke = _expand_kv(k, kv_map, policy, ("batch", "kv_heads", None))
        ve = _expand_kv(v, kv_map, policy, ("batch", "kv_heads", None))
        o, kpg, vpg = attn_fn(q, ke, ve, kpg, vpg, block_table, seq_lens, act,
                              scale=_attn_scale(cfg), window=win,
                              attn_softcap=cfg.attn_logit_softcap)
        attn_out = _o_proj(cfg, lp, o[:, 0], head_mask)
        if cfg.post_attn_norm:
            attn_out = rms_norm(attn_out, lp["ln1b"], cfg.norm_eps)
        xc = xc + attn_out
        h2 = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        y, a = _ffn(cfg, lp, h2, moe_fn)
        if cfg.post_attn_norm:
            y = rms_norm(y, lp["ln2b"], cfg.norm_eps)
        xc = xc + y
        if policy is not None:
            xc = constrain(xc, policy, "batch", None)
        return (xc, aux + a), (kpg, vpg)

    (x, _), (k_pages, v_pages) = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        (params["blocks"], k_pages, v_pages, windows))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(params, cfg, x, policy), (k_pages, v_pages)


# ------------------------------------------------------------------- mixed -
def write_kv_chunk(kpg, vpg, k, v, block_table, start, lens):
    """Scatter a prefill chunk into pages.

    kpg [N, ps, KV_p, hd]; k/v [P, C, KV_p, hd]; block_table [P, Pmax];
    start [P] first position of chunk; lens [P] valid tokens (rest->trash).
    """
    P, C = k.shape[:2]
    ps = kpg.shape[1]
    j = jnp.arange(C, dtype=jnp.int32)[None]                   # [1, C]
    gpos = start[:, None] + j                                  # [P, C]
    page_slot = gpos // ps
    pidx = jnp.take_along_axis(block_table, page_slot, axis=1) # [P, C]
    off = gpos % ps
    trash = kpg.shape[0] - 1
    valid = j < lens[:, None]
    pidx = jnp.where(valid, pidx, trash)
    flat = lambda t: t.reshape((P * C,) + t.shape[2:])
    kpg = kpg.at[flat(pidx), flat(off)].set(flat(k))
    vpg = vpg.at[flat(pidx), flat(off)].set(flat(v))
    return kpg, vpg


def mixed(params, cfg, mb, k_pages, v_pages, *, attn_fn=None, tp=1,
          policy=None, moe_fn=None):
    """Splitwiser fused step: prefill chunks + decode tokens in ONE program.

    mb keys:
      p_tokens [P, C] int32   prefill chunk tokens (pad id 0 beyond p_lens)
      p_table  [P, Pmax]      page table rows for chunk sequences
      p_start  [P]            chunk start position (= history length)
      p_lens   [P]            valid tokens in chunk
      d_tokens [B]            decode tokens
      d_table  [B, Pmax]
      d_lens   [B]            current kv lens (before this step)
      d_active [B] bool

    Every GEMM (QKV/O/FFN/MoE/unembed) runs on the union of prefill and
    decode tokens — the paper's "both phases share the device" realized as
    one fused XLA program. Attention splits by phase.

    Returns (p_logits [P, Vp] at each chunk's last valid token,
             d_logits [B, Vp], (k_pages, v_pages), aux).
    """
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    H_p, KV_p, _, kv_map, head_mask = lay
    decode_attn = (attn_fn or {}).get("decode") if isinstance(attn_fn, dict) else None
    chunk_attn = (attn_fn or {}).get("chunk") if isinstance(attn_fn, dict) else None
    decode_attn = decode_attn or default_decode_attn
    chunk_attn = chunk_attn or default_chunk_attn
    moe_fn = moe_fn or (default_moe_fn(cfg) if cfg.is_moe else None)
    windows = jnp.asarray(layer_windows(cfg))

    P, C = mb["p_tokens"].shape
    B = mb["d_tokens"].shape[0]
    D = cfg.d_model

    xp = embed(params, cfg, mb["p_tokens"], policy)            # [P, C, D]
    xd = embed(params, cfg, mb["d_tokens"], policy)            # [B, D]
    x = jnp.concatenate([xp.reshape(P * C, D), xd], axis=0)    # [P*C+B, D]
    if policy is not None:
        x = constrain(x, policy, "tokens", None)

    jC = jnp.arange(C, dtype=jnp.int32)[None]
    p_pos = mb["p_start"][:, None] + jC                        # [P, C]
    d_pos = mb["d_lens"]

    def body(carry, xs):
        xc, aux = carry
        lp, kpg, vpg, win = xs
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lay, lp, h)                        # the shared GEMM
        qp, qd = q[: P * C].reshape(P, C, H_p, -1), q[P * C :][:, None]
        kp, kd = k[: P * C].reshape(P, C, cfg.n_kv_heads, -1), k[P * C :]
        vp, vd = v[: P * C].reshape(P, C, cfg.n_kv_heads, -1), v[P * C :]

        # --- prefill-phase attention (write chunk KV + attend history) ---
        qp = rope(qp, p_pos, cfg.rope_theta)
        kp = rope(kp, p_pos, cfg.rope_theta)
        kpe = _expand_kv(kp, kv_map)
        vpe = _expand_kv(vp, kv_map)
        o_p, kpg, vpg = chunk_attn(qp, kpe, vpe, kpg, vpg, mb["p_table"],
                                   mb["p_start"], mb["p_lens"],
                                   scale=_attn_scale(cfg), window=win,
                                   attn_softcap=cfg.attn_logit_softcap)

        # --- decode-phase attention ---
        qd = rope(qd, d_pos[:, None], cfg.rope_theta)
        kd = rope(kd[:, None], d_pos[:, None], cfg.rope_theta)[:, 0]
        kde = _expand_kv(kd, kv_map)
        vde = _expand_kv(vd, kv_map)
        o_d, kpg, vpg = decode_attn(qd, kde, vde, kpg, vpg, mb["d_table"],
                                    mb["d_lens"], mb["d_active"],
                                    scale=_attn_scale(cfg), window=win,
                                    attn_softcap=cfg.attn_logit_softcap)

        o = jnp.concatenate([o_p.reshape(P * C, H_p, -1), o_d[:, 0]], axis=0)
        attn_out = _o_proj(cfg, lp, o, head_mask)              # shared GEMM
        if cfg.post_attn_norm:
            attn_out = rms_norm(attn_out, lp["ln1b"], cfg.norm_eps)
        xc = xc + attn_out
        h2 = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        y, a = _ffn(cfg, lp, h2, moe_fn)                       # shared GEMM
        if cfg.post_attn_norm:
            y = rms_norm(y, lp["ln2b"], cfg.norm_eps)
        xc = xc + y
        if policy is not None:
            xc = constrain(xc, policy, "tokens", None)
        return (xc, aux + a), (kpg, vpg)

    (x, aux), (k_pages, v_pages) = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        (params["blocks"], k_pages, v_pages, windows))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)

    xp = x[: P * C].reshape(P, C, D)
    last_idx = jnp.clip(mb["p_lens"] - 1, 0, C - 1)
    xp_last = xp[jnp.arange(P), last_idx]                      # [P, D]
    p_logits = unembed(params, cfg, xp_last, policy)
    d_logits = unembed(params, cfg, x[P * C :], policy)
    return p_logits, d_logits, (k_pages, v_pages), aux


# -------------------------------------------------------------- page utils -
def init_pages(cfg, n_pages, page_size, tp=1, dtype=jnp.float32,
               n_layers=None):
    _, KV_p, _, _, _ = gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, n_pages, page_size, KV_p, cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def kv_to_pages(kv, page_size):
    """Prefill output [L, B, S, KV_p, hd] -> pages [L, B*S/ps, ps, KV_p, hd]."""
    L, B, S, KVp, hd = kv.shape
    if S % page_size:
        raise ValueError(
            f"prefill length S={S} must be page-aligned (page_size="
            f"{page_size}); callers pad the token batch to whole pages")
    return kv.reshape(L, B * (S // page_size), page_size, KVp, hd)
