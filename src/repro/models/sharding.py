"""Logical-axis sharding policy.

Model code annotates activations with *logical* axis names; the launch
layer maps them to mesh axes. On CPU (tests / engine) policy=None and all
annotations are no-ops, so model code never depends on a mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class Policy:
    """logical name -> mesh axis (or tuple of axes)."""
    rules: dict = field(default_factory=dict)
    mesh: Optional[object] = None  # jax Mesh; needed for explicit NamedSharding

    def spec(self, *names: Optional[str]) -> P:
        return P(*[self.rules.get(n) if n else None for n in names])


def constrain(x, policy: Optional[Policy], *names: Optional[str]):
    """with_sharding_constraint by logical dim names; identity w/o policy."""
    if policy is None:
        return x
    spec = policy.spec(*names)
    if policy.mesh is not None:
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(x, NamedSharding(policy.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# Standard rules for the production mesh. "batch"-like dims shard over the
# data axis (and pod in the multi-pod mesh); "heads"/"ff"/"vocab"/"experts"
# shard over the model (tensor) axis; "fsdp" optionally shards a weight dim
# over data for ZeRO-style training.
def make_rules(data_axes=("data",), model_axis="model", fsdp: bool = False):
    return {
        "batch": data_axes if len(data_axes) > 1 else data_axes[0],
        "tokens": data_axes if len(data_axes) > 1 else data_axes[0],  # token-slot dim
        "pages": data_axes if len(data_axes) > 1 else data_axes[0],
        "heads": model_axis,
        "kv_heads": model_axis,
        "ff": model_axis,
        "vocab": model_axis,
        "experts": model_axis,
        "embed": None,
        "fsdp": (data_axes if len(data_axes) > 1 else data_axes[0]) if fsdp else None,
        "seq": None,
    }
