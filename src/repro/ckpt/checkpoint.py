"""Sharded checkpointing with async save, atomic commit, auto-resume and
elastic re-layout.

Format: one .npz per save (leaf arrays keyed by flattened tree path) plus a
JSON manifest. A save is visible only after the COMMIT marker renames into
place, so readers never observe torn checkpoints (power-loss safe).

Elasticity: logical parameter layouts are mesh-independent, so restoring to
a different device count is a pure host-side resharding (jax.device_put
with the new sharding). The one layout that depends on parallelism degree —
GQA head padding — is converted with `relayout_attention_params`.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, state) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **leaves)
    manifest = {
        "step": step,
        "keys": sorted(leaves.keys()),
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)            # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load(ckpt_dir: str, step: int, template=None, shardings=None):
    """Load a checkpoint. With `template` (a pytree), arrays are unflattened
    into its structure; otherwise a nested dict keyed by path is returned.
    With `shardings`, leaves are device_put with them (elastic restore)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"), allow_pickle=False)
    flat = {k: data[k] for k in data.files}
    tree = _unflatten_paths(flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def _unflatten_paths(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _intify(root)


def _intify(node):
    """Convert {'0': a, '1': b} dicts (from lists/tuples) back to lists."""
    if not isinstance(node, dict):
        return node
    node = {k: _intify(v) for k, v in node.items()}
    if node and all(re.fullmatch(r"\d+", k) for k in node):
        return [node[str(i)] for i in range(len(node))]
    return node


class AsyncCheckpointer:
    """Snapshot on the host, write in a background thread (training never
    blocks on disk); double-buffered with atomic commit."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, state):
        self.wait()
        host_state = jax.tree.map(np.asarray, state)   # snapshot now

        def _write():
            self.last_path = save(self.ckpt_dir, step, host_state)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


# --------------------------------------------------------------- elastic ---
def relayout_attention_params(params, cfg, tp_from: int, tp_to: int):
    """Re-layout padded GQA tensors (wq/wo) between TP degrees.

    Real q heads are extracted with the source layout's q_map and
    re-scattered with the target layout's. All other tensors are layout-
    independent. Works on the transformer family's param tree.
    """
    from repro.models.transformer import gqa_layout
    H, KV = cfg.n_heads, cfg.n_kv_heads
    _, _, qm_from, _, _ = gqa_layout(H, KV, tp_from)
    Hp_to, _, qm_to, _, _ = gqa_layout(H, KV, tp_to)

    def relayout(blocks):
        wq, wo = blocks["wq"], blocks["wo"]
        L = wq.shape[0]
        D, hd = wq.shape[1], wq.shape[3]
        wq_real = np.zeros((L, D, H, hd), wq.dtype)
        wo_real = np.zeros((L, H, hd, wo.shape[3]), wo.dtype)
        for slot, real in enumerate(qm_from):
            if real >= 0:
                wq_real[:, :, real] = np.asarray(wq)[:, :, slot]
                wo_real[:, real] = np.asarray(wo)[:, slot]
        wq_new = np.zeros((L, D, Hp_to, hd), wq.dtype)
        wo_new = np.zeros((L, Hp_to, hd, wo.shape[3]), wo.dtype)
        for slot, real in enumerate(qm_to):
            if real >= 0:
                wq_new[:, :, slot] = wq_real[:, :, real]
                wo_new[:, slot] = wo_real[:, real]
        out = dict(blocks)
        out["wq"], out["wo"] = wq_new, wo_new
        return out

    out = dict(params)
    out["blocks"] = relayout(params["blocks"])
    return out
