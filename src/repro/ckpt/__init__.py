from repro.ckpt.checkpoint import (
    AsyncCheckpointer, save, load, latest_step, relayout_attention_params,
)
