"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.

Encoder-decoder, multimodal. [arXiv:2308.11596; hf]. The speech frontend is
a STUB per the assignment: input_specs() provides precomputed frame
embeddings [B, encoder_seq, d_model]; the transformer backbone (12L encoder
+ 12L decoder with cross-attention) is fully implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,               # decoder layers
    n_encoder_layers=12,
    encoder_seq=1024,          # stub frontend frames per utterance
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    rope_theta=10_000.0,
    mlp_act="gelu_mlp",
)
