"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.

GQA (kv=2 — the most bandwidth-skewed decode of the assigned set), RoPE,
classic 2-matrix GELU MLP. [arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49_152,
    rope_theta=100_000.0,
    mlp_act="gelu_mlp",        # non-gated 2-matrix MLP
)
