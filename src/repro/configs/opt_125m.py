"""opt-125m — the paper's own experiment model (facebook/opt-125m dims).

Used by the paper-reproduction benchmarks (Figs. 6-11). Dimensionally
matched stand-in inside our stack (RoPE instead of learned positions;
position-encoding flavor is irrelevant to the
phase-splitting results being reproduced).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-125m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50_272,
    mlp_act="gelu_mlp",
)
