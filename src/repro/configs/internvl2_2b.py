"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.

InternViT + InternLM2. [arXiv:2404.16821; hf]. The vision frontend
(InternViT-300M) is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, n_patches, d_vision]; a learned MLP
projector maps them into the LM embedding space, prepended as prefix
tokens. The InternLM2 backbone is fully implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    n_vision_patches=256,      # 448x448 / 28x28 patches per tile
    d_vision=1024,             # InternViT-300M width
)
