"""Model/serving/training configuration dataclasses.

One frozen `ModelConfig` covers all assigned architecture families
(dense / moe / hybrid / ssm / enc-dec / vlm); per-arch files in this
package instantiate it with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention features ---
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False        # qwen3
    attn_logit_softcap: Optional[float] = None   # gemma2 (50.0)
    final_logit_softcap: Optional[float] = None  # gemma2 (30.0)
    sliding_window: Optional[int] = None         # gemma2 local layers (4096)
    local_global_pattern: Optional[Tuple[str, ...]] = None  # e.g. ("local","global")
    attn_scale_override: Optional[float] = None  # gemma2 query scaling

    # --- mlp ---
    mlp_act: str = "silu"            # silu(SwiGLU) | gelu(GeGLU) | gelu_mlp (2-mat)
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # MoE layer frequency (1 = all layers)
    router_aux_coef: float = 0.01
    # expert capacity factor; reduced() sets no-drop (E/k) so prefill/decode
    # paths are exactly equivalent in tests (capacity dropping is a real,
    # documented property of capacity-based MoE at small batch)
    moe_capacity_factor: float = 1.25

    # --- SSM (mamba2 / rwkv6) ---
    ssm_state: int = 0               # mamba2 state size per head
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    rwkv_head_dim: int = 64
    shared_attn_every: int = 0       # zamba2: a shared attn block every k mamba blocks
    shared_attn_lora_rank: int = 0   # zamba2 per-use LoRA on the shared block

    # --- enc-dec (seamless) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0             # frontend frames per utterance (stub)

    # --- vlm (internvl2) ---
    n_vision_patches: int = 0        # stub frontend: precomputed patch embeds
    d_vision: int = 0

    # --- norms / misc ---
    norm_eps: float = 1e-6
    post_attn_norm: bool = False     # gemma2 uses pre+post norms
    emb_scale_by_sqrt_dim: bool = False  # gemma2

    # shapes supported (used by launch/dryrun cell enumeration)
    supports_decode: bool = True
    supports_long_context: bool = False  # sub-quadratic archs only

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        def shrink(v, lo, cap):
            return max(lo, min(v, cap))
        kw = dict(
            n_layers=shrink(self.n_layers, 2, 2),
            d_model=64,
            n_heads=shrink(self.n_heads, 0, 4) if self.n_heads else 0,
            n_kv_heads=shrink(self.n_kv_heads, 0, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=min(self.vocab_size, 256),
            head_dim=16 if self.n_heads else 0,
        )
        if self.n_experts:
            kw["n_experts"] = min(self.n_experts, 4)
            kw["top_k"] = min(self.top_k, 2)
            kw["moe_capacity_factor"] = float(kw["n_experts"]) / kw["top_k"]
        if self.ssm_state:
            kw["ssm_state"] = min(self.ssm_state, 16)
            kw["ssm_heads"] = min(self.ssm_heads or 4, 4)
        if self.family == "ssm":  # rwkv6
            kw["rwkv_head_dim"] = 16
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
            kw["encoder_seq"] = 16
        if self.n_vision_patches:
            kw["n_vision_patches"] = 8
            kw["d_vision"] = 32
        if self.sliding_window:
            kw["sliding_window"] = 8
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
            kw["shared_attn_lora_rank"] = min(self.shared_attn_lora_rank, 4) or 4
        if self.local_global_pattern:
            kw["local_global_pattern"] = self.local_global_pattern
        return dataclasses.replace(self, name=self.name + "-reduced", **kw)


# Engine execution modes (see core/engine.py for what each arm means);
# "mp2" from the paper is not an engine mode — benchmarks build it from two
# "sequential" replicas (benchmarks/splitwiser_vllm.py).
SERVE_MODES = ("sequential", "splitwiser", "splitwiser_mps", "chunked")


@dataclass(frozen=True)
class TenantTier:
    """Per-tenant SLO tier (``ServeConfig.tenants``).

    Requests name a tenant via ``SLOParams.tenant`` (core/slo.py); the
    matching tier supplies default TTFT/TBT deadlines (per-request
    values override), an in-flight token quota the ``deadline``
    admission policy enforces (a tenant's burst queues behind its quota
    instead of starving other tenants), and a weight the chunked-mode
    planner's carve order scales urgency by (higher weight = served
    earlier at equal slack).  Targets are engine-clock seconds (virtual
    seconds under the counting-clock harnesses).
    """
    name: str
    ttft_target: Optional[float] = None
    tbt_target: Optional[float] = None
    quota_tokens: Optional[int] = None   # max in-flight prompt+budget tokens
    weight: float = 1.0                  # planner carve-order weight

    def __post_init__(self):
        if not isinstance(self.name, str) or not self.name:
            raise ValueError(
                f"tier name must be a non-empty string, got {self.name!r}")
        for knob in ("ttft_target", "tbt_target"):
            value = getattr(self, knob)
            if value is not None and (
                    not isinstance(value, (int, float))
                    or isinstance(value, bool) or value <= 0):
                raise ValueError(
                    f"tier {self.name!r}: {knob} must be a positive number "
                    f"or None, got {value!r}")
        if self.quota_tokens is not None and (
                not isinstance(self.quota_tokens, int)
                or isinstance(self.quota_tokens, bool)
                or self.quota_tokens <= 0):
            raise ValueError(
                f"tier {self.name!r}: quota_tokens must be a positive int "
                f"or None, got {self.quota_tokens!r}")
        if not isinstance(self.weight, (int, float)) \
                or isinstance(self.weight, bool) or self.weight <= 0:
            raise ValueError(
                f"tier {self.name!r}: weight must be a positive number, "
                f"got {self.weight!r}")


@dataclass(frozen=True)
class ServeConfig:
    """Serving-engine (Splitwiser) configuration.

    Sampling knobs live on each request (``SamplingParams`` in
    ``core/sampler.py``), not here: one engine serves heterogeneous
    workloads.
    """
    mode: str = "splitwiser"     # one of SERVE_MODES
    max_batch: int = 64          # max concurrent decode sequences
    token_budget: int = 256      # token slots per mixed step (prefill chunk + decode)
    page_size: int = 16          # tokens per KV page
    n_pages: int = 1024          # global page pool size
    max_pages_per_seq: int = 64
    max_seq_len: int = 1024
    prefill_chunk: int = 128     # chunked-prefill chunk size in mixed mode
    n_streams: int = 2           # parallel prompt-processing streams (paper's #processes)
    chunk_tokens: int = 256      # mode="chunked": per-round packed-token
                                 # budget (core/planner.py) — decode tokens
                                 # claim their share first, prefill chunks
                                 # fill the rest; must be >= page_size
    # --- scheduler: pluggable policies (core/policies.py) ---
    watermark: float = 0.01      # fraction of the page pool kept free at admission
    decode_reserve: float = 0.5  # fraction of remaining max_new_tokens reserved
                                 # as decode headroom when admitting a request
    admission_policy: str = "fcfs"  # fcfs: arrival order (seed behaviour)
                                    # cache_aware: co-schedule resident
                                    #   prefixes, hold twins of in-flight
                                    #   prefills one round so they hit
    admission_age_weight: float = 0.5  # cache_aware aging: resident-prefix
                                    # page advantage one waited round
                                    # offsets, so a cold-prefix request
                                    # cannot starve behind a hot-template
                                    # stream (0 = pure hit-first order)
    eviction_policy: Optional[str] = None  # reclaimable prefix-page strip
                                    # order: lru | fifo | cost (recompute-
                                    # FLOPs model); None inherits
                                    # prefix_cache_policy
    preempt_policy: str = "latest"  # latest: evict latest-arrival + recompute
                                    # cache_aware: prefer victims whose
                                    #   committed KV survives eviction
                                    #   (resume = remap), tie-break latest
                                    # none:   seed behaviour (OutOfPages crash)
    # scheduler-event trace ring size (EngineMetrics.sched_events); oldest
    # events beyond the cap are dropped and counted.  Kept in sync with
    # metrics.DEFAULT_SCHED_EVENTS_CAP (configs stay import-free of core
    # at module load)
    sched_events_cap: int = 16384
    # --- KV page dtype (kernels/kv_int8.py) ---
    # fp:   pages in the model param dtype (seed behaviour)
    # int8: pages as int8 codes + f32 per-(token, head) scale sidecar,
    #       quantized at commit and dequantized inside the attention
    #       kernel; page bytes shrink so the byte-denominated pool holds
    #       ~2x (fp16) to 3.2x (fp32) the pages at equal pool bytes
    kv_dtype: str = "fp"
    # Device-byte budget for the KV page pool.  None sizes the pool as
    # ``n_pages`` *fp-width* pages (so flipping kv_dtype="int8" alone
    # holds pool bytes constant and grows the page count); set explicitly
    # to pin the budget in bytes regardless of n_pages.
    kv_pool_bytes: Optional[int] = None
    # --- shared-prefix KV cache (core/prefix_cache.py) ---
    enable_prefix_cache: bool = False   # refcounted copy-on-write page sharing
    prefix_cache_policy: str = "lru"    # legacy alias for eviction_policy
                                        # (lru | fifo | cost)
    prefix_cache_granularity: str = "token"  # token: partial-page (mid-page
                                        # divergence) reuse via COW of the
                                        # tail page; page: full pages only
                                        # (PR-3 behaviour)
    # --- runtime sanitizer (analysis/invariants.py) ---
    # off:    never check (zero overhead; production default)
    # finish: full cross-module validation after any step finishing a request
    # step:   validate after every engine step (CI runs tier-1 under this)
    # call:   step, plus call-site hooks on every mutating allocator/cache
    #         entry point (violations attributed to the exact call)
    # Defaults from $REPRO_SANITIZE so CI flips whole suites via the
    # environment without touching individual tests.
    sanitize_level: str = field(
        default_factory=lambda: os.environ.get("REPRO_SANITIZE", "off"))
    # --- jit-dispatch sentinel (analysis/dispatch.py) ---
    # Counts XLA compiles per jitted step callable, raises on recompile
    # storms in the step loop, and lets harnesses assert a zero
    # post-warmup recompile budget.  Defaults from $REPRO_DISPATCH_SENTINEL
    # so CI arms whole suites via the environment.
    dispatch_sentinel: bool = field(
        default_factory=lambda: os.environ.get(
            "REPRO_DISPATCH_SENTINEL", "") not in ("", "0", "false", "off"))
    # --- multi-tenant SLO tiers (core/slo.py, core/policies.py) ---
    # Tuple of TenantTier: per-tenant default TTFT/TBT deadlines,
    # in-flight token quotas (enforced by admission_policy="deadline"),
    # and planner carve-order weights.  Empty = single implicit
    # "default" tenant with no deadlines (seed behaviour).
    tenants: Tuple[TenantTier, ...] = ()
    # deadline-admission completion predictor: engine-clock seconds of
    # predicted delay charged per page the admission would allocate
    # (slack = deadline - now - slo_page_cost * admission_pages).  0
    # ranks by raw deadline (pure EDF).
    slo_page_cost: float = 0.0

    def __post_init__(self):
        if self.mode not in SERVE_MODES:
            raise ValueError(
                f"unknown serve mode {self.mode!r}; supported modes: "
                f"{', '.join(SERVE_MODES)}")
        # imported here to keep configs free of core deps at module load
        from repro.core.policies import (ADMISSION_POLICIES,
                                         EVICTION_POLICIES, PREEMPT_POLICIES)
        if self.admission_policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission_policy {self.admission_policy!r}; "
                f"supported: {', '.join(sorted(ADMISSION_POLICIES))}")
        for knob, value in (("eviction_policy", self.eviction_policy),
                            ("prefix_cache_policy", self.prefix_cache_policy)):
            if value is not None and value not in EVICTION_POLICIES:
                raise ValueError(
                    f"unknown {knob} {value!r}; "
                    f"supported: {', '.join(sorted(EVICTION_POLICIES))}")
        if self.preempt_policy not in PREEMPT_POLICIES and \
                self.preempt_policy != "none":
            raise ValueError(
                f"unknown preempt_policy {self.preempt_policy!r}; supported: "
                f"{', '.join(sorted(PREEMPT_POLICIES))}, none")
        if self.kv_dtype not in ("fp", "int8"):
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r}; supported: fp, int8")
        if self.kv_pool_bytes is not None and (
                not isinstance(self.kv_pool_bytes, int)
                or isinstance(self.kv_pool_bytes, bool)
                or self.kv_pool_bytes <= 0):
            raise ValueError(
                f"kv_pool_bytes must be a positive int or None, got "
                f"{self.kv_pool_bytes!r}")
        if self.prefix_cache_granularity not in ("page", "token"):
            raise ValueError(
                f"unknown prefix_cache_granularity "
                f"{self.prefix_cache_granularity!r}; supported: page, token")
        if self.admission_age_weight < 0:
            raise ValueError(
                f"admission_age_weight must be >= 0, got "
                f"{self.admission_age_weight}")
        if self.sched_events_cap <= 0:
            raise ValueError(
                f"sched_events_cap must be positive, got {self.sched_events_cap}")
        for knob in ("max_batch", "token_budget", "page_size", "n_pages",
                     "max_pages_per_seq", "max_seq_len", "prefill_chunk",
                     "n_streams", "chunk_tokens"):
            value = getattr(self, knob)
            if not isinstance(value, int) or isinstance(value, bool) \
                    or value <= 0:
                raise ValueError(
                    f"{knob} must be a positive int, got {value!r}")
        if self.chunk_tokens < self.page_size:
            raise ValueError(
                f"chunk_tokens ({self.chunk_tokens}) must be >= page_size "
                f"({self.page_size}): a chunked round must be able to "
                "commit at least one full KV page")
        if self.n_pages < 2:
            raise ValueError(
                f"n_pages must be >= 2 (page n_pages-1 is the reserved "
                f"trash page), got {self.n_pages}")
        if not 0.0 <= self.watermark < 1.0:
            raise ValueError(
                f"watermark must be in [0, 1), got {self.watermark}")
        if self.decode_reserve < 0:
            raise ValueError(
                f"decode_reserve must be >= 0, got {self.decode_reserve}")
        for knob in ("enable_prefix_cache", "dispatch_sentinel"):
            value = getattr(self, knob)
            if not isinstance(value, bool):
                raise ValueError(f"{knob} must be a bool, got {value!r}")
        if not isinstance(self.tenants, tuple) or any(
                not isinstance(t, TenantTier) for t in self.tenants):
            raise ValueError(
                f"tenants must be a tuple of TenantTier, got {self.tenants!r}")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant tier names: {names}")
        if not isinstance(self.slo_page_cost, (int, float)) \
                or isinstance(self.slo_page_cost, bool) \
                or self.slo_page_cost < 0:
            raise ValueError(
                f"slo_page_cost must be a number >= 0, got "
                f"{self.slo_page_cost!r}")
        from repro.analysis.invariants import SANITIZE_LEVELS
        if self.sanitize_level not in SANITIZE_LEVELS:
            raise ValueError(
                f"unknown sanitize_level {self.sanitize_level!r}; "
                f"supported: {', '.join(SANITIZE_LEVELS)}")

    @property
    def resolved_eviction_policy(self) -> str:
        """The effective reclaimable-page strip order: ``eviction_policy``
        when set, else the legacy ``prefix_cache_policy`` knob."""
        return self.eviction_policy or self.prefix_cache_policy


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    warmup_steps: int = 10
    total_steps: int = 100
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    microbatch: int = 0          # 0 = no grad accumulation
    remat: bool = True
    int8_moments: bool = False   # quantized optimizer state (beyond-paper)
    loss_impl: str = "chunked"   # chunked | vtiled (fused vocab-tiled CE)
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    seed: int = 0
