"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.

MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]. The scale stressor of
the assigned set (~314B params): exercises expert tensor-parallelism
(8 experts < 16-way model axis -> TP inside experts), FSDP optimizer
sharding and int8 moments for the training shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    rope_theta=10_000.0,
    mlp_act="gelu",            # grok uses gated GeLU
    n_experts=8,
    top_k=2,
    attn_logit_softcap=30.0,   # grok-1 attn logit cap
    final_logit_softcap=30.0,
)
