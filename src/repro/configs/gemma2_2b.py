"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local+global alternating attention, attn/final logit softcaps, pre+post
norms, sqrt(d_model)-scaled embeddings. [arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    rope_theta=10_000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    local_global_pattern=("local", "global"),
    mlp_act="gelu",            # GeGLU
    tie_embeddings=True,
    post_attn_norm=True,
    emb_scale_by_sqrt_dim=True,
    attn_scale_override=1.0 / (256 ** 0.5),
)
