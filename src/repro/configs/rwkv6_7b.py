"""rwkv6-7b [ssm] — 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.

RWKV-6 "Finch" — linear attention with data-dependent per-channel decay,
token-shift mixing, O(1) recurrent state. [arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=14336,
    vocab_size=65_536,
    rwkv_head_dim=64,          # 64 wkv heads of dim 64
    supports_long_context=True,
)
