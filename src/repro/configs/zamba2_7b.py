"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000.

Mamba2 backbone + SHARED attention blocks (one weight set, reused), applied
every 6th layer-unit with per-invocation LoRA deltas. ssm_state=64.
[arXiv:2411.15242; unverified]

Layer-unit layout used here: 81 units = 13 groups x (5 mamba2 + 1 shared
attn) + 3 trailing mamba2 units (78 mamba + 13 shared-attn invocations).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,               # total layer-units (see module docstring)
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    rope_theta=10_000.0,
    mlp_act="gelu",
    ssm_state=64,
    ssm_heads=112,             # d_inner(7168) / mamba head dim(64)
    ssm_expand=2,
    ssm_conv_width=4,
    shared_attn_every=6,
    shared_attn_lora_rank=128,
    supports_long_context=True,   # bounded state + 13 attn invocations
)
