"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from repro.configs import (
    qwen3_0_6b, gemma2_2b, phi4_mini_3_8b, starcoder2_3b,
    seamless_m4t_medium, internvl2_2b, olmoe_1b_7b, grok_1_314b,
    zamba2_7b, rwkv6_7b, opt_125m,
)

ARCHS = {
    "qwen3-0.6b": qwen3_0_6b.CONFIG,
    "gemma2-2b": gemma2_2b.CONFIG,
    "phi4-mini-3.8b": phi4_mini_3_8b.CONFIG,
    "starcoder2-3b": starcoder2_3b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "internvl2-2b": internvl2_2b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "grok-1-314b": grok_1_314b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "rwkv6-7b": rwkv6_7b.CONFIG,
    "opt-125m": opt_125m.CONFIG,   # paper's model (not an assigned cell)
}

# The ten assigned architectures (dry-run / roofline cells).
ASSIGNED = [a for a in ARCHS if a != "opt-125m"]


def get_config(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def list_archs():
    return sorted(ARCHS)
