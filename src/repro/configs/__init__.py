from repro.configs.base import ModelConfig, ServeConfig, TrainConfig
from repro.configs.registry import ARCHS, get_config, list_archs
