from repro.configs.base import (SERVE_MODES, ModelConfig, ServeConfig,
                                TenantTier, TrainConfig)
from repro.configs.registry import ARCHS, get_config, list_archs
