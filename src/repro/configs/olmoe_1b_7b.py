"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304.

MoE 64 experts top-8, qk-norm. [arXiv:2409.02060; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,                 # per-expert FFN width
    vocab_size=50_304,
    rope_theta=10_000.0,
    use_qk_norm=True,
    mlp_act="silu",
    n_experts=64,
    top_k=8,
)
